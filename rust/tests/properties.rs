//! Property-based tests over simulator invariants (hand-rolled harness;
//! see `util::testutil`).

use spatzformer::cluster::Cluster;
use spatzformer::config::{Mode, SimConfig};
use spatzformer::isa::{asm, ElemWidth, Instr, Lmul, Program, ScalarOp, VReg, VectorOp};
use spatzformer::kernels::{execute, Deployment, KernelId};
use spatzformer::util::testutil::{check, Gen};

/// Generate a random but well-formed elementwise vector program over a
/// scratch region, returning (program, model closure outputs).
fn arb_elementwise(
    g: &mut Gen,
    n: u32,
    in_base: u32,
    out_base: u32,
    merged: bool,
) -> (Program, Vec<f32>, Vec<f32>) {
    let data: Vec<f32> = (0..n).map(|_| g.f32(100.0)).collect();
    let mut p = Program::new("prop-elementwise");
    let mut expect = data.clone();
    let cap = if merged { 256 } else { 128 };
    let mut off = 0u32;
    while off < n {
        let vl = (g.int(1, cap) as u32).min(n - off);
        p.vector(VectorOp::SetVl { avl: vl, ew: ElemWidth::E32, lmul: Lmul::M8 });
        p.vector(VectorOp::Load { vd: VReg(8), base: in_base + off * 4, stride: 1 });
        let f = g.f32(4.0);
        match g.int(0, 2) {
            0 => {
                p.vector(VectorOp::MulVF { vd: VReg(16), vs: VReg(8), f });
                for e in off..off + vl {
                    expect[e as usize] = data[e as usize] * f;
                }
            }
            1 => {
                p.vector(VectorOp::AddVF { vd: VReg(16), vs: VReg(8), f });
                for e in off..off + vl {
                    expect[e as usize] = data[e as usize] + f;
                }
            }
            _ => {
                p.vector(VectorOp::MovVV { vd: VReg(16), vs: VReg(8) });
                for e in off..off + vl {
                    expect[e as usize] = data[e as usize];
                }
            }
        }
        p.vector(VectorOp::Store { vs: VReg(16), base: out_base + off * 4, stride: 1 });
        if g.bool() {
            p.scalar(ScalarOp::Alu);
        }
        off += vl;
    }
    p.push(Instr::Fence);
    p.push(Instr::Halt);
    (p, data, expect)
}

#[test]
fn prop_split_and_merge_agree_bitwise_on_random_programs() {
    check("split vs merge bitwise", 48, |g| {
        let n = (g.int(1, 24) * 32) as u32;
        let (p, data, expect) = arb_elementwise(g, n, 0, 0x8000, false);
        // split run
        let mut sp = Cluster::new(SimConfig::spatzformer()).unwrap();
        sp.stage_f32(0, &data);
        sp.load_programs([p.clone(), Program::idle()]).unwrap();
        sp.run().unwrap();
        let split_out = sp.tcdm.read_f32_slice(0x8000, n as usize);
        // merge run of the same program (vl <= 128 still valid)
        let mut mg = Cluster::new(SimConfig::spatzformer()).unwrap();
        mg.set_mode(Mode::Merge).unwrap();
        mg.stage_f32(0, &data);
        mg.load_programs([p, Program::idle()]).unwrap();
        mg.run().unwrap();
        let merge_out = mg.tcdm.read_f32_slice(0x8000, n as usize);
        for i in 0..n as usize {
            assert_eq!(split_out[i].to_bits(), expect[i].to_bits(), "split elem {i}");
            assert_eq!(merge_out[i].to_bits(), expect[i].to_bits(), "merge elem {i}");
        }
    });
}

#[test]
fn prop_cycle_counts_are_deterministic() {
    check("determinism", 16, |g| {
        let n = (g.int(1, 8) * 64) as u32;
        let (p, data, _) = arb_elementwise(g, n, 0, 0x8000, false);
        let run = || {
            let mut cl = Cluster::new(SimConfig::spatzformer()).unwrap();
            cl.stage_f32(0, &data);
            cl.load_programs([p.clone(), Program::idle()]).unwrap();
            cl.run().unwrap()
        };
        assert_eq!(run(), run());
    });
}

#[test]
fn prop_energy_monotone_in_work() {
    // doubling the element count must increase energy
    use spatzformer::coordinator::{Coordinator, Job, ModePolicy};
    let mut c = Coordinator::new(SimConfig::spatzformer()).unwrap();
    let mut last = 0.0;
    for kernel in [KernelId::Faxpy, KernelId::Fmatmul] {
        let r = c
            .submit(&Job::Kernel { kernel, policy: ModePolicy::Split })
            .unwrap();
        assert!(r.metrics.energy_pj > 0.0);
        if kernel == KernelId::Fmatmul {
            assert!(
                r.metrics.energy_pj > last,
                "matmul (512x the FLOPs) must cost more than axpy"
            );
        }
        last = r.metrics.energy_pj;
    }
}

/// Any non-NaN f32 bit pattern (NaN is excluded because `Program`'s
/// derived `PartialEq` would reject NaN == NaN, not because the printer
/// mishandles it). Covers subnormals, signed zero and infinities.
fn arb_f32_bits(g: &mut Gen) -> f32 {
    loop {
        let f = f32::from_bits(g.rng.next_u64() as u32);
        if !f.is_nan() {
            return f;
        }
    }
}

/// Seeded round-trip fuzz over the *entire* instruction surface —
/// replaces the previous hand-picked print→parse cases: every scalar op,
/// every vector op (including indexed stores and vv/vf variants with
/// random bit-pattern float immediates), fences, barriers, mode switches
/// and mid-stream halts.
#[test]
fn prop_asm_roundtrip_full_isa_random_programs() {
    check("asm full-ISA roundtrip", 256, |g| {
        let vreg = |g: &mut Gen| VReg(g.int(0, 31) as u8);
        let mut p = Program::new("fuzz");
        let n = g.int(1, 40);
        for _ in 0..n {
            let vd = vreg(g);
            let vs1 = vreg(g);
            let vs2 = vreg(g);
            let base = g.int(0, 1 << 16) as u32;
            let stride = g.int(0, 16) as i32 - 8;
            let instr = match g.int(0, 23) {
                0 => Instr::Scalar(ScalarOp::Alu),
                1 => Instr::Scalar(ScalarOp::Mul),
                2 => Instr::Scalar(ScalarOp::Div),
                3 => Instr::Scalar(ScalarOp::Csr),
                4 => Instr::Scalar(ScalarOp::Nop),
                5 => Instr::Scalar(ScalarOp::Load { addr: base }),
                6 => Instr::Scalar(ScalarOp::Store { addr: base }),
                7 => Instr::Scalar(ScalarOp::Branch { taken: g.bool() }),
                8 => Instr::Fence,
                9 => Instr::Barrier,
                10 => Instr::SetMode(if g.bool() { Mode::Merge } else { Mode::Split }),
                11 => Instr::Halt, // mid-stream halt must survive the printer
                12 => Instr::Vector(VectorOp::SetVl {
                    avl: g.int(0, 1 << 12) as u32,
                    ew: ElemWidth::E32,
                    lmul: Lmul::from_factor(*g.choose(&[1usize, 2, 4, 8])).unwrap(),
                }),
                13 => Instr::Vector(VectorOp::Load { vd, base, stride }),
                14 => Instr::Vector(VectorOp::Store { vs: vd, base, stride }),
                15 => Instr::Vector(VectorOp::LoadIndexed { vd, base, vidx: vs1 }),
                16 => Instr::Vector(VectorOp::StoreIndexed { vs: vd, base, vidx: vs1 }),
                17 => Instr::Vector(VectorOp::AddVV { vd, vs1, vs2 }),
                18 => Instr::Vector(VectorOp::SubVV { vd, vs1, vs2 }),
                19 => Instr::Vector(VectorOp::MulVV { vd, vs1, vs2 }),
                20 => Instr::Vector(match g.int(0, 1) {
                    0 => VectorOp::MacVV { vd, vs1, vs2 },
                    _ => VectorOp::NmsacVV { vd, vs1, vs2 },
                }),
                21 => Instr::Vector(match g.int(0, 2) {
                    0 => VectorOp::AddVF { vd, vs: vs1, f: arb_f32_bits(g) },
                    1 => VectorOp::MulVF { vd, vs: vs1, f: arb_f32_bits(g) },
                    _ => VectorOp::MacVF { vd, vs: vs1, f: arb_f32_bits(g) },
                }),
                22 => Instr::Vector(VectorOp::MovVF { vd, f: arb_f32_bits(g) }),
                _ => Instr::Vector(match g.int(0, 1) {
                    0 => VectorOp::MovVV { vd, vs: vs1 },
                    _ => VectorOp::RedSum { vd, vs: vs1 },
                }),
            };
            p.push(instr);
        }
        p.push(Instr::Halt);
        let text = asm::print_program(&p);
        let q = asm::parse_program(&text)
            .unwrap_or_else(|e| panic!("parse failed: {e}\n{text}"));
        assert_eq!(p, q, "round-trip mismatch:\n{text}");
    });
}

#[test]
fn prop_asm_roundtrip_on_generated_kernels() {
    // every generated kernel program survives print -> parse unchanged
    let cfg = SimConfig::spatzformer();
    for kernel in KernelId::all() {
        for deploy in [Deployment::SplitDual, Deployment::Merge] {
            let inst = kernel.build(&cfg.cluster, deploy, 0x5A5A);
            for p in &inst.programs {
                let text = asm::print_program(p);
                let q = asm::parse_program(&text)
                    .unwrap_or_else(|e| panic!("{} {}: {e}", kernel.name(), deploy.name()));
                assert_eq!(p.as_ref(), &q, "{} {}", kernel.name(), deploy.name());
            }
        }
    }
}

#[test]
fn prop_tcdm_grants_conserve_accesses() {
    // across any kernel run: granted accesses == element mem ops issued
    // by the vector units + scalar memory ops (no lost/phantom grants)
    for kernel in KernelId::all() {
        let cfg = SimConfig::spatzformer();
        let inst = kernel.build(&cfg.cluster, Deployment::SplitDual, 0x31);
        let mut cl = Cluster::new(cfg).unwrap();
        let (m, _) = execute(&mut cl, &inst).unwrap();
        let expected = m.counters.vec_elem_mem + m.counters.scalar_mem;
        assert_eq!(
            m.tcdm.accesses, expected,
            "{}: accesses {} != issued {}",
            kernel.name(),
            m.tcdm.accesses,
            expected
        );
    }
}

#[test]
fn prop_fpu_utilization_bounded() {
    for kernel in KernelId::all() {
        for deploy in [Deployment::SplitDual, Deployment::Merge] {
            let cfg = SimConfig::spatzformer();
            let inst = kernel.build(&cfg.cluster, deploy, 0x31);
            let mut cl = Cluster::new(cfg).unwrap();
            let (m, _) = execute(&mut cl, &inst).unwrap();
            let u = m.fpu_utilization(2, 4);
            assert!(
                (0.0..=1.0).contains(&u),
                "{} {}: utilization {u}",
                kernel.name(),
                deploy.name()
            );
        }
    }
}

#[test]
fn prop_gather_scatter_random_permutations() {
    check("gather/scatter permutation roundtrip", 32, |g| {
        let n = (g.int(1, 4) * 64) as usize;
        let mut cl = Cluster::new(SimConfig::spatzformer()).unwrap();
        let data: Vec<f32> = (0..n).map(|_| g.f32(10.0)).collect();
        // random permutation as byte offsets
        let mut perm: Vec<usize> = (0..n).collect();
        for i in (1..n).rev() {
            let j = g.int(0, i);
            perm.swap(i, j);
        }
        let idx: Vec<u32> = perm.iter().map(|&p| (p * 4) as u32).collect();
        cl.stage_f32(0, &data);
        cl.stage_u32(0x4000, &idx);
        let mut p = Program::new("perm");
        let mut off = 0usize;
        while off < n {
            let vl = (n - off).min(128) as u32;
            p.vector(VectorOp::SetVl { avl: vl, ew: ElemWidth::E32, lmul: Lmul::M8 });
            p.vector(VectorOp::Load { vd: VReg(0), base: 0x4000 + (off * 4) as u32, stride: 1 });
            p.vector(VectorOp::LoadIndexed { vd: VReg(8), base: 0, vidx: VReg(0) });
            p.vector(VectorOp::Store { vs: VReg(8), base: 0x8000 + (off * 4) as u32, stride: 1 });
            off += vl as usize;
        }
        p.push(Instr::Fence);
        p.push(Instr::Halt);
        cl.load_programs([p, Program::idle()]).unwrap();
        cl.run().unwrap();
        let out = cl.tcdm.read_f32_slice(0x8000, n);
        for i in 0..n {
            assert_eq!(out[i].to_bits(), data[perm[i]].to_bits(), "elem {i}");
        }
    });
}

// ---- util::json: seeded encode→parse round-trip fuzz (the wire codec
// behind spatzd), in the same style as the asm print→parse fuzz ----

/// Random finite f64: integers, uniform ranges, tiny/huge magnitudes,
/// pool edge cases, and raw random bit patterns (filtered to finite).
fn arb_f64(g: &mut Gen) -> f64 {
    match g.int(0, 5) {
        0 => (g.rng.next_u64() >> 12) as f64, // exact integers < 2^52
        1 => -((g.rng.next_u64() >> 40) as f64),
        2 => *g.choose(&[
            0.0,
            -0.0,
            1.5,
            -1.0,
            1e300,
            -1e300,
            5e-324, // smallest subnormal
            f64::MIN_POSITIVE,
            9007199254740992.0,  // 2^53: integral but outside the exact range
            -9007199254740994.0, // -(2^53+2): ditto, negative
            f64::MAX,
        ]),
        3 => g.rng.next_f64() * 1e6 - 5e5,
        4 => g.rng.next_f64() * 1e-300,
        _ => {
            let bits = f64::from_bits(g.rng.next_u64());
            if bits.is_finite() {
                bits
            } else {
                g.rng.next_f64()
            }
        }
    }
}

/// Random string over a pool that covers every escape class: quotes,
/// backslashes, the short escapes, raw control chars, multi-byte UTF-8.
fn arb_json_string(g: &mut Gen) -> String {
    let pool = [
        'a', 'Z', '0', ' ', '"', '\\', '/', '\n', '\r', '\t', '\u{8}', '\u{c}', '\u{1}',
        '\u{1f}', 'é', 'ü', '中', '🚀', '\u{fffd}',
    ];
    g.vec(0, 24, |g| *g.choose(&pool)).into_iter().collect()
}

fn arb_json(g: &mut Gen, depth: usize) -> spatzformer::util::Json {
    use spatzformer::util::Json;
    if depth >= 4 || g.int(0, 2) == 0 {
        match g.int(0, 3) {
            0 => Json::Null,
            1 => Json::Bool(g.bool()),
            2 => Json::Num(arb_f64(g)),
            _ => Json::Str(arb_json_string(g)),
        }
    } else if g.bool() {
        Json::Arr(g.vec(0, 5, |g| arb_json(g, depth + 1)))
    } else {
        Json::Obj(g.vec(0, 5, |g| (arb_json_string(g), arb_json(g, depth + 1))))
    }
}

#[test]
fn prop_json_encode_parse_roundtrip() {
    use spatzformer::util::Json;
    check("json encode→parse roundtrip", 512, |g| {
        let v = arb_json(g, 0);
        let encoded = v.encode();
        let back = Json::parse(&encoded)
            .unwrap_or_else(|e| panic!("own encoding must parse: {e}\n{encoded}"));
        assert_eq!(back, v, "roundtrip diverged: {encoded}");
        // canonical: encoding a decoded value is a fixed point
        assert_eq!(back.encode(), encoded);
    });
}

#[test]
fn prop_json_numbers_roundtrip_bit_exactly() {
    use spatzformer::util::Json;
    check("json f64 bit-exact roundtrip", 512, |g| {
        let x = arb_f64(g);
        let encoded = Json::Num(x).encode();
        let back = Json::parse(&encoded).unwrap().as_f64().unwrap();
        assert_eq!(
            back.to_bits(),
            x.to_bits(),
            "{x:?} -> {encoded} -> {back:?}"
        );
    });
}

#[test]
fn prop_json_rejects_trailing_garbage_and_survives_truncation() {
    use spatzformer::util::Json;
    check("json malformed-input handling", 256, |g| {
        let v = arb_json(g, 0);
        let encoded = v.encode();
        // a complete document followed by another token must be rejected
        for suffix in ["x", "[1]", "\"s\"", "1"] {
            let doc = format!("{encoded} {suffix}");
            assert!(Json::parse(&doc).is_err(), "accepted trailing garbage: {doc}");
        }
        // truncating anywhere must error or parse cleanly — never panic
        let cut = g.int(0, encoded.len());
        if encoded.is_char_boundary(cut) {
            let _ = Json::parse(&encoded[..cut]);
        }
    });
}

#[test]
fn prop_perf_record_codec_roundtrips_every_field() {
    use spatzformer::trace::perf::{Kind, Record, RECORD_BYTES};
    check("perf record encode/decode roundtrip", 512, |g| {
        let kind = Kind::from_u8(g.int(1, 13) as u8).expect("kinds 1..=13 are valid");
        let rec = Record {
            cycle: g.rng.next_u64(),
            kind,
            who: (g.rng.next_u64() & 0xff) as u8,
            a: (g.rng.next_u64() & 0xffff) as u16,
            b: (g.rng.next_u64() & 0xffff_ffff) as u32,
            c: g.rng.next_u64(),
            d: g.rng.next_u64(),
        };
        let bytes = rec.encode();
        assert_eq!(bytes.len(), RECORD_BYTES);
        let back = Record::decode(&bytes).expect("valid kind must decode");
        assert_eq!(back, rec, "roundtrip must preserve every field");
        // corrupting the kind byte to an out-of-range value must be
        // rejected, never misdecoded
        let mut bad = bytes;
        bad[8] = *g.choose(&[0u8, 14, 200, 255]);
        assert!(Record::decode(&bad).is_none(), "kind {} accepted", bad[8]);
    });
}
