//! The `spatzd` service contract, proven over loopback:
//!
//! (a) **byte-identity** — a served `JobReport` is byte-identical to a
//!     direct `Coordinator` run of the same job, for a kernel ×
//!     deployment grid on both architectures (decoded reports compare
//!     `PartialEq`-equal *and* the response's report node re-encodes to
//!     the exact bytes the direct report encodes to);
//! (b) **admission control** — a request that does not fit the bounded
//!     queue gets an explicit `429`-style reject response, never a hang
//!     or a silent drop, and the daemon keeps serving afterwards;
//! (c) **replayability** — `loadgen` with the same seed reproduces the
//!     same request stream, and a live loadgen run against the daemon
//!     answers every request.
//!
//! Plus: batch digests are deterministic and match locally computed
//! reports, and shutdown drains cleanly.

use spatzformer::config::SimConfig;
use spatzformer::coordinator::{Coordinator, Job, JobReport, ModePolicy};
use spatzformer::fleet::scenario::{self, ScenarioKind};
use spatzformer::kernels::KernelId;
use spatzformer::server::{self, loadgen, proto, RunningServer};
use spatzformer::trace::service as svc;
use spatzformer::util::Json;
use std::io::{BufRead, BufReader, BufWriter, Write};
use std::net::{SocketAddr, TcpStream};

/// Start an in-process daemon on an ephemeral loopback port.
fn start(mut cfg: SimConfig) -> RunningServer {
    cfg.server.addr = "127.0.0.1:0".to_string();
    server::serve(cfg).expect("daemon failed to start")
}

/// One client connection speaking the line protocol.
struct Client {
    reader: BufReader<TcpStream>,
    writer: BufWriter<TcpStream>,
}

impl Client {
    fn connect(addr: SocketAddr) -> Client {
        let stream = TcpStream::connect(addr).expect("connect to spatzd");
        let read_half = stream.try_clone().unwrap();
        Client {
            reader: BufReader::new(read_half),
            writer: BufWriter::new(stream),
        }
    }

    /// Send one request line, return the decoded response.
    fn roundtrip(&mut self, line: &str) -> Json {
        self.send(line);
        self.read_response()
    }

    /// Send without waiting — protocol v2 pipelining.
    fn send(&mut self, line: &str) {
        writeln!(self.writer, "{line}").unwrap();
        self.writer.flush().unwrap();
    }

    /// Read and decode the next response line.
    fn read_response(&mut self) -> Json {
        let mut response = String::new();
        let n = self.reader.read_line(&mut response).unwrap();
        assert!(n > 0, "daemon closed the connection mid-request");
        Json::parse(response.trim()).unwrap_or_else(|e| {
            panic!("unparseable response: {e}\n{response}")
        })
    }

    fn submit(&mut self, job: &Job) -> Json {
        self.roundtrip(&proto::encode_request(&proto::Request::Submit {
            job: job.clone(),
            seed: None,
        }))
    }
}

fn assert_ok(resp: &Json) {
    assert_eq!(
        resp.get("ok").and_then(Json::as_bool),
        Some(true),
        "expected success: {resp}"
    );
}

/// (a) The determinism contract, kernel × policy grid on both arches.
#[test]
fn served_reports_are_byte_identical_to_direct_coordinator_runs() {
    for baseline in [false, true] {
        let cfg = if baseline {
            SimConfig::baseline()
        } else {
            SimConfig::spatzformer()
        };
        let mut jobs: Vec<Job> = Vec::new();
        let policies: &[ModePolicy] = if baseline {
            &[ModePolicy::Split, ModePolicy::Auto]
        } else {
            &[ModePolicy::Split, ModePolicy::Merge, ModePolicy::Auto]
        };
        for kernel in KernelId::all() {
            for &policy in policies {
                jobs.push(Job::Kernel { kernel, policy });
            }
        }
        jobs.push(Job::Mixed {
            kernel: KernelId::Fft,
            policy: ModePolicy::Auto,
            coremark_iterations: 2,
        });
        jobs.push(Job::Mixed {
            kernel: KernelId::Faxpy,
            policy: ModePolicy::Split,
            coremark_iterations: 1,
        });

        let daemon = start(cfg.clone());
        let mut client = Client::connect(daemon.addr());
        let mut direct_coord = Coordinator::new(cfg.clone()).unwrap();
        for job in &jobs {
            let resp = client.submit(job);
            assert_ok(&resp);
            let node = resp.get("report").expect("submit response carries a report");
            let served = proto::report_from_json(node)
                .unwrap_or_else(|e| panic!("{}: {e:#}", job.name()));
            let direct = direct_coord.submit(job).unwrap();
            assert_eq!(
                served, direct,
                "served report diverges from direct run ({}, baseline={baseline})",
                job.name()
            );
            // byte-level: the wire node re-encodes to exactly what the
            // direct report encodes to
            assert_eq!(
                node.encode(),
                proto::report_to_json(&direct).encode(),
                "wire bytes diverge ({})",
                job.name()
            );
        }
        drop(client);
        daemon.shutdown();
        daemon.wait().unwrap();
    }
}

/// (b) Admission control: an oversized request is refused explicitly
/// and immediately; the daemon stays healthy.
#[test]
fn full_queue_yields_explicit_reject_not_a_hang() {
    let mut cfg = SimConfig::spatzformer();
    cfg.server.queue_depth = 2;
    cfg.server.workers = 1;
    let daemon = start(cfg);
    let mut client = Client::connect(daemon.addr());

    // 64 jobs can never fit a 2-slot queue: explicit 429, all-or-nothing
    let resp = client.roundtrip(&proto::encode_request(&proto::Request::Batch {
        kind: ScenarioKind::Storm,
        jobs: 64,
        seed: Some(7),
        reports: false,
    }));
    assert_eq!(resp.get("ok").and_then(Json::as_bool), Some(false));
    assert_eq!(resp.get("code").and_then(Json::as_u64), Some(429));
    assert!(
        resp.get("error").and_then(Json::as_str).unwrap().contains("queue full"),
        "{resp}"
    );

    // the reject is visible in status, and the daemon still serves
    let status = client.roundtrip(&proto::encode_request(&proto::Request::Status));
    assert_ok(&status);
    assert_eq!(status.get("accepting").and_then(Json::as_bool), Some(true));
    assert!(status.get("rejected").and_then(Json::as_u64).unwrap() >= 1);

    let resp = client.roundtrip(&proto::encode_request(&proto::Request::Batch {
        kind: ScenarioKind::Storm,
        jobs: 2,
        seed: Some(7),
        reports: false,
    }));
    assert_ok(&resp);
    assert_eq!(resp.get("jobs").and_then(Json::as_u64), Some(2));
    assert!(resp.get("digest").and_then(Json::as_str).unwrap().starts_with("0x"));

    // a malformed line is a 400, not a dropped connection
    let resp = client.roundtrip("{\"op\":\"fly\"}");
    assert_eq!(resp.get("code").and_then(Json::as_u64), Some(400));

    drop(client);
    daemon.shutdown();
    daemon.wait().unwrap();
}

/// Batch responses are deterministic and their digest matches reports
/// computed directly, without the daemon.
#[test]
fn batch_digest_matches_locally_computed_reports() {
    let cfg = SimConfig::spatzformer();
    let daemon = start(cfg.clone());
    let mut client = Client::connect(daemon.addr());
    let req = proto::encode_request(&proto::Request::Batch {
        kind: ScenarioKind::KernelSweep,
        jobs: 10,
        seed: Some(0xFEED),
        reports: false,
    });
    let first = client.roundtrip(&req);
    let second = client.roundtrip(&req);
    assert_ok(&first);
    let digest = first.get("digest").and_then(Json::as_str).unwrap();
    assert_eq!(
        Some(digest),
        second.get("digest").and_then(Json::as_str),
        "same batch twice must digest identically"
    );

    // local oracle: same scenario through one coordinator
    let batch = scenario::generate(ScenarioKind::KernelSweep, cfg.cluster.arch, 0xFEED, 10);
    let mut coord = Coordinator::new(cfg.clone()).unwrap();
    let reports: Vec<JobReport> = batch
        .jobs
        .iter()
        .map(|fj| {
            coord.set_seed(fj.seed.unwrap_or(cfg.seed));
            coord.submit(&fj.job).unwrap()
        })
        .collect();
    let local = format!("{:#018x}", proto::reports_digest(reports.iter()));
    assert_eq!(digest, local, "served digest must match the local oracle");
    assert_eq!(
        first.get("sim_cycles_total").and_then(Json::as_u64).unwrap(),
        reports.iter().map(|r| r.metrics.cycles).sum::<u64>()
    );

    drop(client);
    daemon.shutdown();
    daemon.wait().unwrap();
}

/// (c) loadgen determinism + a live run that answers every request.
#[test]
fn loadgen_replays_deterministically_and_round_trips() {
    let cfg = SimConfig::spatzformer();
    // same seed ⇒ byte-identical request stream, per client
    for client in 0..3 {
        let a = loadgen::request_lines(
            cfg.cluster.arch,
            ScenarioKind::Storm,
            42,
            client,
            12,
        );
        let b = loadgen::request_lines(
            cfg.cluster.arch,
            ScenarioKind::Storm,
            42,
            client,
            12,
        );
        assert_eq!(a, b, "client {client} stream must replay exactly");
    }

    let daemon = start(cfg);
    let opts = loadgen::LoadgenOptions {
        addr: daemon.addr().to_string(),
        clients: 2,
        requests: 4,
        seed: 42,
        scenario: ScenarioKind::Storm,
        send_shutdown: false,
        ..Default::default()
    };
    let report = loadgen::run(&opts).unwrap();
    assert_eq!(report.sent, 8);
    assert_eq!(report.ok, 8, "{report:?}");
    assert_eq!((report.rejected, report.errors), (0, 0), "{report:?}");
    assert!(report.jobs_per_sec() > 0.0);
    assert!(report.latency.is_some());
    assert!(report.render().contains("jobs/s"));

    // metrics endpoint saw exactly those 8 submits
    let mut client = Client::connect(daemon.addr());
    let metrics = client.roundtrip(&proto::encode_request(&proto::Request::Metrics));
    assert_ok(&metrics);
    assert_eq!(metrics.get("submits").and_then(Json::as_u64), Some(8));
    assert_eq!(metrics.get("jobs_completed").and_then(Json::as_u64), Some(8));
    // latency windows split per request type: 8 submits populate the
    // submit window, the batch/status windows stay explicit nulls
    let lat = metrics.get("latency_ms").unwrap();
    assert!(lat.get("submit").unwrap().get("p99_ms").and_then(Json::as_f64).is_some(), "{lat}");
    assert_eq!(lat.get("batch"), Some(&Json::Null), "{lat}");
    assert!(metrics.get("result_cache_hits").is_some());
    assert!(metrics.get("compile_cache_misses").is_some());

    drop(client);
    daemon.shutdown();
    daemon.wait().unwrap();
}

/// The wire shutdown op drains the daemon; afterwards the port is dead.
#[test]
fn wire_shutdown_stops_the_daemon_cleanly() {
    let daemon = start(SimConfig::spatzformer());
    let addr = daemon.addr();
    let mut client = Client::connect(addr);
    // do some work first so the final snapshot is non-trivial
    let resp = client.submit(&Job::Kernel {
        kernel: KernelId::Faxpy,
        policy: ModePolicy::Split,
    });
    assert_ok(&resp);
    let ack = client.roundtrip(&proto::encode_request(&proto::Request::Shutdown));
    assert_ok(&ack);
    assert_eq!(ack.get("shutting_down").and_then(Json::as_bool), Some(true));
    drop(client);

    let snapshot = daemon.wait().unwrap();
    assert_eq!(snapshot.submits, 1);
    assert_eq!(snapshot.jobs_completed, 1);
    assert!(snapshot.render().contains("jobs/s"));
    // the listener is gone: fresh connections are refused
    assert!(
        TcpStream::connect(addr).is_err(),
        "daemon must stop listening after shutdown"
    );
}

/// Protocol v2: two requests in one flush; the cheap `status` overtakes
/// the simulation, and tags match each response back to its request.
#[test]
fn pipelined_requests_answer_out_of_order_by_tag() {
    let cfg = SimConfig::spatzformer();
    let daemon = start(cfg.clone());
    let mut client = Client::connect(daemon.addr());
    let job = Job::Kernel { kernel: KernelId::Fdotp, policy: ModePolicy::Split };
    let submit = proto::encode_request_tagged(
        &proto::Request::Submit { job: job.clone(), seed: None },
        &Json::str("slow"),
    );
    let status = proto::encode_request_tagged(&proto::Request::Status, &Json::u64_lossless(42));
    client.send(&submit);
    client.send(&status);
    // status answers first: its response is queued while the submit is
    // still inside the worker pool
    let first = client.read_response();
    assert_eq!(first.get("id").and_then(Json::as_u64), Some(42), "{first}");
    assert_ok(&first);
    assert!(first.get("queue_depth").and_then(Json::as_u64).unwrap() >= 1, "{first}");
    assert!(first.get("in_flight").and_then(Json::as_u64).is_some(), "{first}");
    assert!(first.get("connections").and_then(Json::as_u64).unwrap() >= 1, "{first}");
    let second = client.read_response();
    assert_eq!(second.get("id").and_then(Json::as_str), Some("slow"), "{second}");
    assert_ok(&second);
    // out-of-order delivery does not perturb the report bytes
    let direct = Coordinator::new(cfg).unwrap().submit(&job).unwrap();
    assert_eq!(
        second.get("report").unwrap().encode(),
        proto::report_to_json(&direct).encode(),
        "pipelined report must stay byte-identical to the direct run"
    );
    drop(client);
    daemon.shutdown();
    daemon.wait().unwrap();
}

/// A client that pipelines past the per-connection in-flight cap without
/// reading gets explicit tagged `429`s, never a hang — and every tag is
/// answered exactly once.
#[test]
fn pipelining_past_the_inflight_cap_rejects_explicitly() {
    let mut cfg = SimConfig::spatzformer();
    cfg.server.workers = 1;
    cfg.server.queue_depth = 256;
    let daemon = start(cfg);
    let mut client = Client::connect(daemon.addr());
    let total = 100usize; // > the 64-request per-connection cap
    for i in 0..total {
        let line = proto::encode_request_tagged(
            &proto::Request::Submit {
                job: Job::Kernel { kernel: KernelId::Faxpy, policy: ModePolicy::Split },
                seed: None,
            },
            &Json::u64_lossless(i as u64),
        );
        writeln!(client.writer, "{line}").unwrap();
    }
    client.writer.flush().unwrap();
    let mut seen = vec![0usize; total];
    let (mut ok, mut rejected) = (0u64, 0u64);
    for _ in 0..total {
        let resp = client.read_response();
        let id = resp.get("id").and_then(Json::as_u64).expect("every response is tagged") as usize;
        assert!(id < total, "{resp}");
        seen[id] += 1;
        if resp.get("ok").and_then(Json::as_bool) == Some(true) {
            ok += 1;
        } else {
            assert_eq!(resp.get("code").and_then(Json::as_u64), Some(429), "{resp}");
            rejected += 1;
        }
    }
    assert!(seen.iter().all(|&n| n == 1), "every tag answered exactly once: {seen:?}");
    assert_eq!(ok + rejected, total as u64);
    assert!(ok >= 1, "some requests must be admitted");
    assert!(rejected >= 1, "the cap must trip when 100 requests pipeline unread");
    // the connection and the daemon both survive the overload
    let status = client.roundtrip(&proto::encode_request(&proto::Request::Status));
    assert_ok(&status);
    drop(client);
    daemon.shutdown();
    daemon.wait().unwrap();
}

/// The shard router forwards by result-cache digest, keeps reports
/// byte-identical through the extra hop, survives pipelined tags, and
/// broadcasts shutdown to every backend.
#[test]
fn router_preserves_byte_identity_and_shards_by_digest() {
    let cfg = SimConfig::spatzformer();
    let d1 = start(cfg.clone());
    let d2 = start(cfg.clone());
    let router = server::router::start(
        cfg.clone(),
        server::router::RouterOptions {
            addr: "127.0.0.1:0".to_string(),
            backends: vec![d1.addr().to_string(), d2.addr().to_string()],
        },
    )
    .unwrap();
    let mut client = Client::connect(router.addr());
    let job = Job::Kernel { kernel: KernelId::Fdotp, policy: ModePolicy::Merge };
    let resp = client.submit(&job);
    assert_ok(&resp);
    let direct = Coordinator::new(cfg.clone()).unwrap().submit(&job).unwrap();
    assert_eq!(
        resp.get("report").unwrap().encode(),
        proto::report_to_json(&direct).encode(),
        "the router hop must not perturb report bytes"
    );
    // digest affinity: the duplicate lands on the same backend, whose
    // result cache serves it — visible in the backends' own metrics
    let resp2 = client.submit(&job);
    assert_ok(&resp2);
    assert_eq!(resp.get("report").unwrap().encode(), resp2.get("report").unwrap().encode());
    let hits: u64 = [d1.addr(), d2.addr()]
        .iter()
        .map(|&a| {
            let mut c = Client::connect(a);
            let m = c.roundtrip(&proto::encode_request(&proto::Request::Metrics));
            m.get("result_cache_hits").and_then(Json::as_u64).unwrap()
        })
        .sum();
    assert!(hits >= 1, "duplicate submit must re-hit one backend's result cache");
    // client tags survive the double rewrite (client id -> internal seq -> client id)
    let resp = client.roundtrip(&proto::encode_request_tagged(
        &proto::Request::Status,
        &Json::str("st-9"),
    ));
    assert_eq!(resp.get("id").and_then(Json::as_str), Some("st-9"), "{resp}");
    assert_ok(&resp);
    // wire shutdown broadcasts: both backends stop, then the router acks
    let ack = client.roundtrip(&proto::encode_request(&proto::Request::Shutdown));
    assert_ok(&ack);
    assert_eq!(ack.get("shutting_down").and_then(Json::as_bool), Some(true));
    drop(client);
    router.wait().unwrap();
    d1.wait().unwrap();
    d2.wait().unwrap();
}

/// Open-loop loadgen: the seeded schedule replays, every request is
/// answered (ok or explicit reject), nothing hangs, nothing errors.
#[test]
fn open_loop_loadgen_answers_every_scheduled_request() {
    let daemon = start(SimConfig::spatzformer());
    let opts = loadgen::LoadgenOptions {
        addr: daemon.addr().to_string(),
        clients: 4,
        requests: 5,
        seed: 11,
        rate: Some(200.0),
        ..Default::default()
    };
    let report = loadgen::run(&opts).unwrap();
    assert_eq!(report.sent, 20);
    assert_eq!(report.ok + report.rejected, 20, "{report:?}");
    assert_eq!(report.errors, 0, "{report:?}");
    assert!(report.ok > 0, "{report:?}");
    assert!(report.render().contains("open-loop"), "{}", report.render());
    daemon.shutdown();
    daemon.wait().unwrap();
}

/// `batch` with `"reports": true` returns inline per-job reports that
/// match the local oracle byte-for-byte; past `server.batch_report_limit`
/// the refusal is explicit and happens before any job runs.
#[test]
fn batch_inline_reports_match_the_oracle_and_stay_bounded() {
    let mut cfg = SimConfig::spatzformer();
    cfg.server.batch_report_limit = 2;
    let daemon = start(cfg.clone());
    let mut client = Client::connect(daemon.addr());
    let resp = client.roundtrip(&proto::encode_request(&proto::Request::Batch {
        kind: ScenarioKind::KernelSweep,
        jobs: 2,
        seed: Some(5),
        reports: true,
    }));
    assert_ok(&resp);
    let reports = match resp.get("reports") {
        Some(Json::Arr(a)) => a,
        other => panic!("expected an inline reports array, got {other:?}"),
    };
    assert_eq!(reports.len(), 2);
    let batch = scenario::generate(ScenarioKind::KernelSweep, cfg.cluster.arch, 5, 2);
    let mut coord = Coordinator::new(cfg.clone()).unwrap();
    for (node, fj) in reports.iter().zip(&batch.jobs) {
        coord.set_seed(fj.seed.unwrap_or(cfg.seed));
        let direct = coord.submit(&fj.job).unwrap();
        assert_eq!(
            node.encode(),
            proto::report_to_json(&direct).encode(),
            "inline batch report must match the direct run byte-for-byte"
        );
    }
    // over the bound: explicit 429 before generation, not a truncated array
    let resp = client.roundtrip(&proto::encode_request(&proto::Request::Batch {
        kind: ScenarioKind::KernelSweep,
        jobs: 3,
        seed: Some(5),
        reports: true,
    }));
    assert_eq!(resp.get("code").and_then(Json::as_u64), Some(429), "{resp}");
    assert!(
        resp.get("error").and_then(Json::as_str).unwrap().contains("batch_report_limit"),
        "{resp}"
    );
    // the bound is on inline reports only — the same batch without the
    // flag runs fine and stays digest-only
    let resp = client.roundtrip(&proto::encode_request(&proto::Request::Batch {
        kind: ScenarioKind::KernelSweep,
        jobs: 3,
        seed: Some(5),
        reports: false,
    }));
    assert_ok(&resp);
    assert!(resp.get("reports").is_none(), "{resp}");
    drop(client);
    daemon.shutdown();
    daemon.wait().unwrap();
}

/// Service tracing is write-only: a daemon with `server.trace` on
/// serves byte-identical reports to an untraced daemon and to a direct
/// coordinator run, and responses never echo the trace id.
#[test]
fn service_tracing_never_changes_served_bytes() {
    let cfg = SimConfig::spatzformer();
    let mut traced_cfg = cfg.clone();
    traced_cfg.server.trace = true;
    let plain = start(cfg.clone());
    let traced = start(traced_cfg);
    let mut pc = Client::connect(plain.addr());
    let mut tc = Client::connect(traced.addr());
    let jobs = [
        Job::Kernel { kernel: KernelId::Fft, policy: ModePolicy::Auto },
        Job::Mixed { kernel: KernelId::Faxpy, policy: ModePolicy::Split, coremark_iterations: 1 },
    ];
    let mut direct = Coordinator::new(cfg).unwrap();
    for job in &jobs {
        let a = pc.submit(job);
        let b = tc.submit(job);
        assert_ok(&a);
        assert_ok(&b);
        assert!(b.get("trace").is_none(), "responses must not echo the trace id: {b}");
        assert_eq!(
            a.encode(),
            b.encode(),
            "service tracing changed the served bytes ({})",
            job.name()
        );
        let oracle = direct.submit(job).unwrap();
        assert_eq!(
            b.get("report").unwrap().encode(),
            proto::report_to_json(&oracle).encode(),
            "traced daemon diverged from the direct run ({})",
            job.name()
        );
    }
    drop(pc);
    drop(tc);
    plain.shutdown();
    traced.shutdown();
    plain.wait().unwrap();
    traced.wait().unwrap();
}

fn temp_trace_path(tag: &str) -> std::path::PathBuf {
    std::env::temp_dir().join(format!("spatzformer-svc-{}-{tag}.sptz", std::process::id()))
}

/// Saturate one worker with pipelined submits and check the span
/// algebra: every request decomposes into recv → admit → queue-wait →
/// execute → encode → flush with consistent timestamps, and the
/// queue-wait stage actually measures waiting (some job waited while
/// its predecessor held the only worker).
#[test]
fn service_trace_spans_decompose_queue_wait_under_saturation() {
    let sink = temp_trace_path("queuewait");
    let mut cfg = SimConfig::spatzformer();
    cfg.server.workers = 1;
    cfg.server.trace = true;
    cfg.server.trace_out = sink.to_string_lossy().into_owned();
    let daemon = start(cfg);
    let mut client = Client::connect(daemon.addr());
    let total = 6usize;
    for i in 0..total {
        client.send(&proto::encode_request_tagged(
            &proto::Request::Submit {
                job: Job::Kernel { kernel: KernelId::Faxpy, policy: ModePolicy::Split },
                seed: None,
            },
            &Json::u64_lossless(i as u64),
        ));
    }
    for _ in 0..total {
        assert_ok(&client.read_response());
    }
    drop(client);
    daemon.shutdown();
    let snap = daemon.wait().unwrap();
    assert!(snap.queue_wait.is_some(), "snapshot must surface queue-wait percentiles");
    assert!(snap.service_trace_records > 0);

    let records = svc::read_trace_file(&sink).expect("trace sink must parse back");
    std::fs::remove_file(&sink).ok();
    // fold per-trace stage timelines: (t_us, dur_us) per stage
    use std::collections::BTreeMap;
    let mut by_trace: BTreeMap<u64, BTreeMap<u8, (u64, u64)>> = BTreeMap::new();
    for r in &records {
        by_trace.entry(r.trace_id).or_default().insert(r.stage as u8, (r.t_us, r.dur_us));
    }
    let full: Vec<_> = by_trace
        .values()
        .filter(|stages| stages.contains_key(&(svc::Stage::Execute as u8)))
        .collect();
    assert_eq!(full.len(), total, "every submit must leave a full lifecycle");
    let mut waited = 0usize;
    for stages in &full {
        let recv = stages[&(svc::Stage::Recv as u8)];
        let admit = stages[&(svc::Stage::Admit as u8)];
        let qw = stages[&(svc::Stage::QueueWait as u8)];
        let exec = stages[&(svc::Stage::Execute as u8)];
        let enc = stages[&(svc::Stage::Encode as u8)];
        let flush = stages[&(svc::Stage::Flush as u8)];
        assert!(recv.0 <= qw.0, "recv must precede enqueue");
        assert!(qw.0 <= admit.0, "enqueue happens inside admission");
        assert!(qw.0 + qw.1 <= exec.0, "queue wait ends before execution starts");
        assert!(exec.0 <= enc.0, "execution precedes response encoding");
        assert!(enc.0 <= flush.0, "encoding precedes the socket flush");
        if qw.1 > 0 {
            waited += 1;
        }
    }
    assert!(
        waited >= 1,
        "with one worker and {total} pipelined submits, someone must have waited"
    );
    // the offline query decomposes the same data: each slowest entry
    // carries the full stage count (the CI smoke asserts >= 3)
    let report = svc::service_query(&records, &svc::ServiceFilter::default(), 3);
    assert_eq!(report.requests_total, total as u64);
    assert!(report.slowest.iter().all(|r| r.stages >= 3), "{:?}", report.slowest);
    let sub = svc::ServiceFilter { op: Some(svc::op::SUBMIT), ..Default::default() };
    assert_eq!(svc::service_query(&records, &sub, 3).requests_total, total as u64);
}

/// The router's `metrics` op fans out to every healthy backend and
/// returns one aggregated snapshot whose counters are exactly the sum
/// of the per-backend sub-documents it embeds.
#[test]
fn router_metrics_aggregates_across_backends() {
    let cfg = SimConfig::spatzformer();
    let d1 = start(cfg.clone());
    let d2 = start(cfg.clone());
    let router = server::router::start(
        cfg,
        server::router::RouterOptions {
            addr: "127.0.0.1:0".to_string(),
            backends: vec![d1.addr().to_string(), d2.addr().to_string()],
        },
    )
    .unwrap();
    let mut client = Client::connect(router.addr());
    let mut sent = 0u64;
    for kernel in KernelId::all() {
        let resp = client.submit(&Job::Kernel { kernel, policy: ModePolicy::Split });
        assert_ok(&resp);
        sent += 1;
    }
    let m = client.roundtrip(&proto::encode_request(&proto::Request::Metrics));
    assert_ok(&m);
    let backends = match m.get("backends") {
        Some(Json::Obj(fields)) => fields,
        other => panic!("aggregated metrics must embed per-backend docs, got {other:?}"),
    };
    assert_eq!(backends.len(), 2, "both backends must answer the fan-out");
    for (addr, _) in backends {
        assert!(
            [d1.addr().to_string(), d2.addr().to_string()].contains(addr),
            "sub-docs are keyed by backend address, got {addr}"
        );
    }
    for key in ["requests", "submits", "jobs_completed", "rejected", "errors"] {
        let total = m.get(key).and_then(Json::as_u64).unwrap_or_else(|| panic!("no {key}: {m}"));
        let parts: u64 = backends
            .iter()
            .map(|(_, d)| d.get(key).and_then(Json::as_u64).unwrap())
            .sum();
        assert_eq!(total, parts, "aggregated {key} must equal the per-backend sum");
    }
    assert_eq!(m.get("submits").and_then(Json::as_u64), Some(sent));
    let completed: u64 = backends
        .iter()
        .map(|(_, d)| d.get("jobs_completed").and_then(Json::as_u64).unwrap())
        .sum();
    assert_eq!(completed, sent, "every routed submit completed on some backend");

    let ack = client.roundtrip(&proto::encode_request(&proto::Request::Shutdown));
    assert_ok(&ack);
    drop(client);
    router.wait().unwrap();
    d1.wait().unwrap();
    d2.wait().unwrap();
}

/// Health probes: a backend that dies is marked down after the failure
/// threshold and the shard map routes around it; `status` surfaces the
/// transition.
#[test]
fn router_probes_detect_dead_backend_and_reroute() {
    let mut cfg = SimConfig::spatzformer();
    cfg.server.probe_ms = 25;
    cfg.server.probe_threshold = 2;
    let d1 = start(cfg.clone());
    let d2 = start(cfg.clone());
    let router = server::router::start(
        cfg,
        server::router::RouterOptions {
            addr: "127.0.0.1:0".to_string(),
            backends: vec![d1.addr().to_string(), d2.addr().to_string()],
        },
    )
    .unwrap();
    let dead_addr = d1.addr().to_string();
    // kill backend 1 out from under the router
    let mut direct = Client::connect(d1.addr());
    assert_ok(&direct.roundtrip(&proto::encode_request(&proto::Request::Shutdown)));
    drop(direct);
    d1.wait().unwrap();

    let mut client = Client::connect(router.addr());
    let deadline = std::time::Instant::now() + std::time::Duration::from_secs(10);
    loop {
        let status = client.roundtrip(&proto::encode_request(&proto::Request::Status));
        assert_ok(&status);
        assert_eq!(status.get("router").and_then(Json::as_bool), Some(true));
        let entry = status.get("backends").and_then(|b| b.get(&dead_addr)).unwrap();
        if entry.get("healthy").and_then(Json::as_bool) == Some(false) {
            assert!(
                entry.get("down_transitions").and_then(Json::as_u64).unwrap() >= 1,
                "{status}"
            );
            break;
        }
        assert!(
            std::time::Instant::now() < deadline,
            "router never marked the dead backend down: {status}"
        );
        std::thread::sleep(std::time::Duration::from_millis(20));
    }
    // every submit now lands on the survivor, whatever its digest prefers
    for kernel in [KernelId::Faxpy, KernelId::Fdotp, KernelId::Fft] {
        let resp = client.submit(&Job::Kernel { kernel, policy: ModePolicy::Split });
        assert_ok(&resp);
    }
    let ack = client.roundtrip(&proto::encode_request(&proto::Request::Shutdown));
    assert_ok(&ack);
    drop(client);
    router.wait().unwrap();
    d2.wait().unwrap();
}

/// `loadgen --shutdown` (the CI smoke path) works end to end.
#[test]
fn loadgen_can_stop_the_daemon_it_tested() {
    let daemon = start(SimConfig::spatzformer());
    let opts = loadgen::LoadgenOptions {
        addr: daemon.addr().to_string(),
        clients: 1,
        requests: 2,
        seed: 9,
        send_shutdown: true,
        ..Default::default()
    };
    let report = loadgen::run(&opts).unwrap();
    assert_eq!(report.ok, 2);
    let snapshot = daemon.wait().unwrap();
    assert_eq!(snapshot.jobs_completed, 2);
}
