//! Coordinator-level integration: job queues, policies, reports, and the
//! paper's headline comparisons at the framework surface.

use spatzformer::config::SimConfig;
use spatzformer::coordinator::{Coordinator, Job, ModePolicy};
use spatzformer::kernels::{Deployment, KernelId};

#[test]
fn full_queue_of_all_kernels_and_modes() {
    let mut c = Coordinator::new(SimConfig::spatzformer()).unwrap();
    let mut jobs = Vec::new();
    for kernel in KernelId::all() {
        jobs.push(Job::Kernel { kernel, policy: ModePolicy::Split });
        jobs.push(Job::Kernel { kernel, policy: ModePolicy::Merge });
    }
    let reports = c.run_queue(&jobs).unwrap();
    assert_eq!(reports.len(), 12);
    for r in &reports {
        assert!(r.metrics.cycles > 0, "{}", r.job_name);
        assert!(r.metrics.energy_pj > 0.0, "{}", r.job_name);
        assert!(r.flop_per_cycle() > 0.0, "{}", r.job_name);
    }
}

#[test]
fn merge_never_catastrophically_slower_and_fft_faster() {
    let mut c = Coordinator::new(SimConfig::spatzformer()).unwrap();
    for kernel in KernelId::all() {
        let sm = c
            .submit(&Job::Kernel { kernel, policy: ModePolicy::Split })
            .unwrap();
        let mm = c
            .submit(&Job::Kernel { kernel, policy: ModePolicy::Merge })
            .unwrap();
        let ratio = sm.kernel_cycles as f64 / mm.kernel_cycles as f64;
        assert!(ratio > 0.85, "{}: MM {ratio:.2}x of SM", kernel.name());
        if kernel == KernelId::Fft {
            // the paper's headline: MM fft beats SM by a clear margin
            assert!(ratio > 1.10, "fft MM speedup only {ratio:.2}x");
        }
    }
}

#[test]
fn mixed_workload_speedup_matches_paper_band() {
    // Fig. 2 right axis: MM speedup of kernel ∥ CoreMark over SM,
    // average ~1.8x, up to ~2x
    let mut c = Coordinator::new(SimConfig::spatzformer()).unwrap();
    let mut speedups = Vec::new();
    for kernel in KernelId::all() {
        let sm = c
            .submit(&Job::Mixed { kernel, policy: ModePolicy::Split, coremark_iterations: 1 })
            .unwrap();
        let mm = c
            .submit(&Job::Mixed { kernel, policy: ModePolicy::Merge, coremark_iterations: 1 })
            .unwrap();
        speedups.push(sm.kernel_cycles as f64 / mm.kernel_cycles as f64);
    }
    let geo = spatzformer::util::Summary::from_samples(&speedups).geomean();
    assert!(
        (1.5..2.1).contains(&geo),
        "mixed-workload average speedup {geo:.2} outside the paper band"
    );
    let max = speedups.iter().cloned().fold(0.0, f64::max);
    assert!(max <= 2.05, "speedup above the 2-unit bound: {max:.2}");
}

#[test]
fn coremark_work_proof_is_mode_independent() {
    let mut c = Coordinator::new(SimConfig::spatzformer()).unwrap();
    let sm = c
        .submit(&Job::Mixed {
            kernel: KernelId::Fdotp,
            policy: ModePolicy::Split,
            coremark_iterations: 2,
        })
        .unwrap();
    let mm = c
        .submit(&Job::Mixed {
            kernel: KernelId::Fdotp,
            policy: ModePolicy::Merge,
            coremark_iterations: 2,
        })
        .unwrap();
    assert_eq!(sm.coremark_checksum, mm.coremark_checksum);
}

#[test]
fn energy_efficiency_relations_match_paper_shape() {
    // SM Spatzformer loses a little EE to the baseline (reconfig logic
    // power); MM recovers most of it (fetch amortization)
    let kernel = KernelId::Faxpy;
    let run = |cfg: SimConfig, policy| {
        let mut c = Coordinator::new(cfg).unwrap();
        let r = c.submit(&Job::Kernel { kernel, policy }).unwrap();
        r.metrics.gflops_per_watt()
    };
    let base = run(SimConfig::baseline(), ModePolicy::Split);
    let sm = run(SimConfig::spatzformer(), ModePolicy::Split);
    let mm = run(SimConfig::spatzformer(), ModePolicy::Merge);
    assert!(sm < base, "SM must pay for reconfigurability (sm={sm}, base={base})");
    assert!(mm > sm, "MM must recover efficiency via fetch amortization");
    let sm_drop = (base - sm) / base;
    assert!(sm_drop < 0.10, "SM drop {:.1}% too large", sm_drop * 100.0);
}

#[test]
fn deployment_resolution_rules() {
    let mut base = Coordinator::new(SimConfig::baseline()).unwrap();
    // Auto on baseline mixed -> split-single
    let r = base
        .submit(&Job::Mixed {
            kernel: KernelId::Faxpy,
            policy: ModePolicy::Auto,
            coremark_iterations: 1,
        })
        .unwrap();
    assert_eq!(r.deploy, Deployment::SplitSingle);
    // Merge on baseline -> error
    assert!(base
        .submit(&Job::Kernel { kernel: KernelId::Faxpy, policy: ModePolicy::Merge })
        .is_err());
}
