//! Cluster-level integration: cross-module behaviours that unit tests
//! can't see — barrier/fence interplay under load, contention between
//! scalar and vector traffic, merge-mode equivalences.

use spatzformer::cluster::Cluster;
use spatzformer::config::{Mode, SimConfig};
use spatzformer::isa::{ElemWidth, Instr, Lmul, Program, ScalarOp, VReg, VectorOp};
use spatzformer::kernels::{execute, Deployment, KernelId};
use spatzformer::workloads::coremark;

#[test]
fn all_kernels_split_dual_equal_baseline_cycles() {
    // SM Spatzformer must be cycle-identical to the baseline cluster:
    // the broadcast stage is bypassed in split mode (paper: SM == base).
    for kernel in KernelId::all() {
        let run = |cfg: SimConfig| {
            let inst = kernel.build(&cfg.cluster, Deployment::SplitDual, 0x77);
            let mut cl = Cluster::new(cfg).unwrap();
            let (m, _) = execute(&mut cl, &inst).unwrap();
            m.cycles
        };
        let base = run(SimConfig::baseline());
        let sm = run(SimConfig::spatzformer());
        assert_eq!(base, sm, "{}: SM must match baseline", kernel.name());
    }
}

#[test]
fn merge_mode_outputs_equal_split_outputs() {
    // functional equivalence of deployments (same final memory content)
    for kernel in KernelId::all() {
        let mut outs = Vec::new();
        for deploy in [Deployment::SplitDual, Deployment::SplitSingle, Deployment::Merge] {
            let cfg = SimConfig::spatzformer();
            let inst = kernel.build(&cfg.cluster, deploy, 0x99);
            let mut cl = Cluster::new(cfg).unwrap();
            let (_, o) = execute(&mut cl, &inst).unwrap();
            outs.push(o);
        }
        // kernels whose programs use the same vl in split-single and
        // merge (fixed row vectors) are bit-identical across modes;
        // max-vl kernels (axpy/dotp/fft) re-strip at the doubled vl and
        // may legitimately reassociate accumulation.
        let fixed_vl = matches!(kernel, KernelId::Fmatmul | KernelId::Conv2d | KernelId::Fdct);
        if fixed_vl {
            for (a, b) in outs[1].iter().zip(outs[2].iter()) {
                let bits_equal = a
                    .iter()
                    .zip(b.iter())
                    .all(|(x, y)| x.to_bits() == y.to_bits());
                assert!(bits_equal, "{}: single vs merge not bit-identical", kernel.name());
            }
        } else {
            for (a, b) in outs[1].iter().zip(outs[2].iter()) {
                spatzformer::util::stats::assert_allclose(a, b, 1e-3, 1e-3);
            }
        }
        for (a, b) in outs[0].iter().zip(outs[2].iter()) {
            spatzformer::util::stats::assert_allclose(a, b, 1e-3, 1e-3);
        }
    }
}

#[test]
fn scalar_traffic_contends_with_vector_traffic() {
    // a memory-hammering scalar co-runner must slow a memory-bound kernel
    let kernel_cycles = |with_scalar: bool| {
        let cfg = SimConfig::spatzformer();
        let mut inst = KernelId::Faxpy.build(&cfg.cluster, Deployment::SplitSingle, 5);
        if with_scalar {
            let w = coremark(&cfg.cluster, 2, 5);
            inst.programs[1] = std::sync::Arc::new(w.program);
        }
        let mut cl = Cluster::new(cfg).unwrap();
        execute(&mut cl, &inst).unwrap();
        cl.core_halt_cycle(0).unwrap()
    };
    let solo = kernel_cycles(false);
    let contended = kernel_cycles(true);
    assert!(
        contended >= solo,
        "contention cannot speed the kernel up (solo={solo}, contended={contended})"
    );
}

#[test]
fn mode_switch_under_load_preserves_results() {
    // alternate modes across strips of an elementwise op; result must be
    // exactly the same data as a pure split run
    let n = 1024u32;
    let data: Vec<f32> = (0..n).map(|i| (i as f32).sin()).collect();

    let run = |switchy: bool| -> Vec<f32> {
        let mut cl = Cluster::new(SimConfig::spatzformer()).unwrap();
        cl.stage_f32(0, &data);
        let mut p = Program::new("switchy");
        let mut off = 0u32;
        let mut mode = Mode::Split;
        while off < n {
            let vl = if mode == Mode::Merge { 256 } else { 128 };
            let vl = vl.min(n - off);
            p.vector(VectorOp::SetVl { avl: vl, ew: ElemWidth::E32, lmul: Lmul::M8 });
            p.vector(VectorOp::Load { vd: VReg(8), base: off * 4, stride: 1 });
            p.vector(VectorOp::MulVF { vd: VReg(16), vs: VReg(8), f: 3.0 });
            p.vector(VectorOp::Store { vs: VReg(16), base: 0x8000 + off * 4, stride: 1 });
            off += vl;
            if switchy && off < n {
                mode = if mode == Mode::Split { Mode::Merge } else { Mode::Split };
                p.push(Instr::SetMode(mode));
            }
        }
        p.push(Instr::Fence);
        p.push(Instr::Halt);
        cl.load_programs([p, Program::idle()]).unwrap();
        cl.run().unwrap();
        cl.tcdm.read_f32_slice(0x8000, n as usize)
    };

    let plain = run(false);
    let switched = run(true);
    assert_eq!(plain, switched);
}

#[test]
fn mode_switch_costs_cycles() {
    let run = |switches: usize| -> u64 {
        let mut cl = Cluster::new(SimConfig::spatzformer()).unwrap();
        let mut p = Program::new("cost");
        for _ in 0..switches {
            p.push(Instr::SetMode(Mode::Merge));
            p.push(Instr::SetMode(Mode::Split));
        }
        for _ in 0..32 {
            p.scalar(ScalarOp::Alu);
        }
        p.push(Instr::Halt);
        cl.load_programs([p, Program::idle()]).unwrap();
        cl.run().unwrap()
    };
    let none = run(0);
    let ten = run(10);
    let per_switch = (ten - none) as f64 / 20.0;
    // each switch pays >= mode_switch_latency
    assert!(
        per_switch >= SimConfig::default().cluster.mode_switch_latency as f64,
        "per_switch={per_switch}"
    );
}

#[test]
fn fft_barrier_count_scales_with_stages() {
    let cfg = SimConfig::spatzformer();
    let inst = KernelId::Fft.build(&cfg.cluster, Deployment::SplitDual, 3);
    let mut cl = Cluster::new(cfg).unwrap();
    let (m, _) = execute(&mut cl, &inst).unwrap();
    // 1 bitrev barrier + 8 stage barriers, 2 arrivals each
    assert_eq!(m.counters.barriers, 18);
    assert!(m.counters.barrier_wait_cycles > 0);
}

#[test]
fn dma_staging_tracked_separately_from_kernel_cycles() {
    let cfg = SimConfig::spatzformer();
    let inst = KernelId::Fdotp.build(&cfg.cluster, Deployment::Merge, 3);
    let mut cl = Cluster::new(cfg).unwrap();
    let (m, _) = execute(&mut cl, &inst).unwrap();
    // 2 x 8192 f32 staged at 8 B/cycle = 8192 cycles of DMA
    assert!(m.dma_cycles >= 8192, "dma={}", m.dma_cycles);
    assert!(m.cycles < 10_000, "kernel cycles include staging?");
}
