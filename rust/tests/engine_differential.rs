//! Differential harness for the cluster cycle-loop engines.
//!
//! The event-driven fast-forward engine ([`EngineKind::Fast`]) must be
//! **byte-identical** to the naive per-cycle oracle ([`EngineKind::Naive`]):
//! exact [`JobReport`] `PartialEq` (every counter, every stat, the priced
//! energy) across the full kernel × deployment grid, mixed and storm
//! scenarios, seeded random programs, and — crucially — the `max_cycles`
//! watchdog, which must fire at the identical cycle with identical
//! accumulated state even when the trip point lands mid-skip.

use spatzformer::cluster::Cluster;
use spatzformer::config::{ArchKind, EngineKind, Mode, SimConfig};
use spatzformer::coordinator::{Coordinator, Job, JobReport, ModePolicy};
use spatzformer::fleet::scenario::{self, ScenarioKind};
use spatzformer::fleet::FleetJob;
use spatzformer::isa::{ElemWidth, Instr, Lmul, Program, ScalarOp, VReg, VectorOp};
use spatzformer::kernels::KernelId;
use spatzformer::util::testutil::{check, Gen};

/// Run one fleet job sequentially under the given engine.
fn run_with(engine: EngineKind, base: &SimConfig, fj: &FleetJob) -> JobReport {
    let mut cfg = fj.config(base);
    cfg.engine = engine;
    let mut coord = Coordinator::new(cfg).expect("config must validate");
    coord.submit(&fj.job).expect("job must simulate")
}

fn assert_engines_agree(base: &SimConfig, jobs: &[FleetJob]) {
    for (i, fj) in jobs.iter().enumerate() {
        let fast = run_with(EngineKind::Fast, base, fj);
        let naive = run_with(EngineKind::Naive, base, fj);
        assert_eq!(
            fast,
            naive,
            "job {i} ({}) diverged between engines",
            fj.job.name()
        );
    }
}

#[test]
fn kernel_deployment_grid_is_engine_invariant() {
    let spatz = SimConfig::spatzformer();
    let mut jobs = Vec::new();
    for kernel in KernelId::all() {
        for policy in [ModePolicy::Split, ModePolicy::Merge, ModePolicy::Auto] {
            jobs.push(FleetJob::new(Job::Kernel { kernel, policy }));
        }
    }
    assert_engines_agree(&spatz, &jobs);

    let baseline = SimConfig::baseline();
    let mut jobs = Vec::new();
    for kernel in KernelId::all() {
        for policy in [ModePolicy::Split, ModePolicy::Auto] {
            jobs.push(FleetJob::new(Job::Kernel { kernel, policy }));
        }
    }
    assert_engines_agree(&baseline, &jobs);
}

#[test]
fn mixed_jobs_are_engine_invariant() {
    let spatz = SimConfig::spatzformer();
    let mut jobs = Vec::new();
    for kernel in KernelId::all() {
        for policy in [ModePolicy::Split, ModePolicy::Merge, ModePolicy::Auto] {
            jobs.push(FleetJob::new(Job::Mixed {
                kernel,
                policy,
                coremark_iterations: 1,
            }));
        }
    }
    assert_engines_agree(&spatz, &jobs);
}

#[test]
fn mixed_sweep_and_storm_scenarios_are_engine_invariant() {
    let spatz = SimConfig::spatzformer();
    let mixed = scenario::generate(ScenarioKind::MixedSweep, ArchKind::Spatzformer, 0xD1FF, 16);
    assert_engines_agree(&spatz, &mixed.jobs);
    let storm = scenario::generate(ScenarioKind::Storm, ArchKind::Spatzformer, 0xD1FF, 20);
    assert_engines_agree(&spatz, &storm.jobs);

    let baseline = SimConfig::baseline();
    let storm_b = scenario::generate(ScenarioKind::Storm, ArchKind::Baseline, 0x5707, 12);
    assert_engines_agree(&baseline, &storm_b.jobs);
}

#[test]
fn prop_random_topologies_are_engine_invariant() {
    // The dual-core contract, generalized: whatever cores × clusters
    // shape a job pins, the fast engine must stay byte-identical to the
    // per-cycle oracle — exact `JobReport` equality, topology included.
    check("fast vs naive over random topologies", 10, |g| {
        let base = SimConfig::spatzformer();
        let cores = g.int(1, 4);
        let clusters = g.int(1, 2);
        let kernels = KernelId::all();
        let kernel = kernels[g.int(0, kernels.len() - 1)];
        // merge pairs adjacent cores and mixed needs a free scalar core:
        // both require at least two cores per cluster
        let policy = if cores >= 2 && g.bool() { ModePolicy::Merge } else { ModePolicy::Split };
        let job = if cores >= 2 && g.bool() {
            Job::Mixed { kernel, policy, coremark_iterations: 1 }
        } else {
            Job::Kernel { kernel, policy }
        };
        let fj = FleetJob::with_topology(job, cores, clusters);
        let fast = run_with(EngineKind::Fast, &base, &fj);
        let naive = run_with(EngineKind::Naive, &base, &fj);
        assert_eq!(
            fast,
            naive,
            "{} {policy:?} diverged at cores={cores} clusters={clusters}",
            kernel.name()
        );
    });
}

/// Full post-run cluster fingerprint for cluster-level comparisons.
fn fingerprint(cl: &Cluster, out_base: u32, out_len: usize) -> (u64, String, Vec<u32>) {
    let m = cl.metrics(0);
    let mem: Vec<u32> = cl
        .tcdm
        .read_f32_slice(out_base, out_len)
        .into_iter()
        .map(f32::to_bits)
        .collect();
    (cl.now(), format!("{:?}|{:?}|{:?}", m.counters, m.tcdm, m.icache), mem)
}

/// Random but valid dual-core workload: elementwise strips with matched
/// barrier counts, optional runtime mode switches (scalar-only co-runner
/// in that variant), scalar bookkeeping and fences — the state space the
/// fast-forward engine has to get right.
fn arb_dual_core(g: &mut Gen) -> (SimConfig, [Program; 2], Vec<f32>) {
    let n = (g.int(1, 8) * 32) as u32;
    let data: Vec<f32> = (0..n * 2).map(|_| g.f32(50.0)).collect();
    let switchy = g.bool();
    let barriers = g.int(0, 2);
    let mut p0 = Program::new("diff-p0");
    let mut p1 = Program::new("diff-p1");
    let strip = |p: &mut Program, g: &mut Gen, in_base: u32, out_base: u32, n: u32, cap: u32| {
        let mut off = 0u32;
        while off < n {
            let vl = (g.int(1, cap as usize) as u32).min(n - off);
            p.vector(VectorOp::SetVl { avl: vl, ew: ElemWidth::E32, lmul: Lmul::M8 });
            // mixed strides: 1 hits the closed-form conflict-free path,
            // 2/3 exercise the general conflict-schedule replay
            let stride = g.int(1, 3) as i32;
            p.vector(VectorOp::Load { vd: VReg(8), base: in_base + off * 4, stride });
            match g.int(0, 2) {
                0 => p.vector(VectorOp::MulVF { vd: VReg(16), vs: VReg(8), f: g.f32(4.0) }),
                1 => p.vector(VectorOp::MacVF { vd: VReg(16), vs: VReg(8), f: g.f32(2.0) }),
                _ => p.vector(VectorOp::AddVF { vd: VReg(16), vs: VReg(8), f: g.f32(4.0) }),
            }
            p.vector(VectorOp::Store { vs: VReg(16), base: out_base + off * 4, stride: 1 });
            if g.bool() {
                p.scalar(ScalarOp::Alu);
            }
            if g.bool() {
                p.scalar(ScalarOp::Branch { taken: g.bool() });
            }
            off += vl;
        }
    };
    if switchy {
        // core 0 toggles modes between strips; core 1 stays scalar-only
        // (merge mode forbids vector work on core 1)
        strip(&mut p0, g, 0, 0x10000, n, 128);
        for _ in 0..barriers {
            p0.push(Instr::Fence);
            p0.push(Instr::Barrier);
            p1.push(Instr::Barrier);
        }
        p0.push(Instr::Fence);
        p0.push(Instr::SetMode(Mode::Merge));
        strip(&mut p0, g, 0, 0x14000, n, 256);
        p0.push(Instr::Fence);
        p0.push(Instr::SetMode(Mode::Split));
        for _ in 0..g.int(0, 40) {
            match g.int(0, 3) {
                0 => p1.scalar(ScalarOp::Alu),
                1 => p1.scalar(ScalarOp::Mul),
                2 => p1.scalar(ScalarOp::Load { addr: (g.int(0, 1024) * 4) as u32 }),
                _ => p1.scalar(ScalarOp::Div),
            }
        }
    } else {
        // split mode: both cores work disjoint halves with matched barriers
        strip(&mut p0, g, 0, 0x10000, n, 128);
        strip(&mut p1, g, n * 4, 0x14000, n, 128);
        for _ in 0..barriers {
            p0.push(Instr::Fence);
            p1.push(Instr::Fence);
            p0.push(Instr::Barrier);
            p1.push(Instr::Barrier);
        }
    }
    p0.push(Instr::Fence);
    p0.push(Instr::Halt);
    p1.push(Instr::Fence);
    p1.push(Instr::Halt);
    (SimConfig::spatzformer(), [p0, p1], data)
}

#[test]
fn prop_random_programs_are_engine_invariant() {
    check("fast vs naive on random dual-core programs", 24, |g| {
        let (cfg, programs, data) = arb_dual_core(g);
        let run = |engine: EngineKind| {
            let mut cfg = cfg.clone();
            cfg.engine = engine;
            let mut cl = Cluster::new(cfg).unwrap();
            cl.stage_f32(0, &data);
            cl.load_programs([programs[0].clone(), programs[1].clone()]).unwrap();
            cl.run().unwrap();
            // cover both output regions (0x10000.. and 0x14000..)
            fingerprint(&cl, 0x10000, 4352)
        };
        assert_eq!(run(EngineKind::Fast), run(EngineKind::Naive));
    });
}

/// Build, stage and run one program pair under `engine`; returns the
/// fingerprint plus the TCDM and DMA tallies the conflict fast-forward
/// must reproduce exactly.
#[allow(clippy::type_complexity)]
fn run_programs(
    base: &SimConfig,
    engine: EngineKind,
    programs: &[Program; 2],
    stage_f32: &[(u32, Vec<f32>)],
    stage_u32: &[(u32, Vec<u32>)],
    out: (u32, usize),
) -> ((u64, String, Vec<u32>), spatzformer::mem::TcdmStats, u64, spatzformer::mem::DmaStats) {
    let mut cfg = base.clone();
    cfg.engine = engine;
    let mut cl = Cluster::new(cfg).unwrap();
    for (addr, d) in stage_f32 {
        cl.stage_f32(*addr, d);
    }
    for (addr, d) in stage_u32 {
        cl.stage_u32(*addr, d);
    }
    cl.load_programs([programs[0].clone(), programs[1].clone()]).unwrap();
    cl.run().unwrap();
    let fp = fingerprint(&cl, out.0, out.1);
    let tcdm = cl.tcdm.stats.clone();
    let dma = cl.dma.stats.clone();
    (fp, tcdm, cl.dma_cycles, dma)
}

/// Same-bank broadcast gather: every element of a `LoadIndexed` hits the
/// identical address, so each arbitration cycle grants once and replays
/// `lanes - 1` conflicts — the worst case for the conflict-schedule
/// oracle's general path. Both arches, reports and conflict counts
/// byte-identical, and the conflicts must actually be there.
#[test]
fn same_bank_broadcast_gathers_are_engine_invariant() {
    for base in [SimConfig::spatzformer(), SimConfig::baseline()] {
        let mut p0 = Program::new("gather-bcast");
        p0.vector(VectorOp::SetVl { avl: 64, ew: ElemWidth::E32, lmul: Lmul::M8 });
        // v8 <- index table (all entries the same byte offset)
        p0.vector(VectorOp::Load { vd: VReg(8), base: 0x2000, stride: 1 });
        // v16[i] = mem[0 + idx[i]] — a 64-wide broadcast of one word
        p0.vector(VectorOp::LoadIndexed { vd: VReg(16), base: 0, vidx: VReg(8) });
        p0.vector(VectorOp::Store { vs: VReg(16), base: 0x6000, stride: 1 });
        p0.push(Instr::Fence);
        p0.push(Instr::Halt);
        let programs = [p0, Program::idle()];
        let stage_f32 = vec![(0u32, vec![0.0f32; 256]), (1024, vec![42.5f32])];
        let stage_u32 = vec![(0x2000u32, vec![1024u32; 64])];
        let run = |engine| {
            run_programs(&base, engine, &programs, &stage_f32, &stage_u32, (0x6000, 64))
        };
        let fast = run(EngineKind::Fast);
        let naive = run(EngineKind::Naive);
        assert_eq!(fast, naive, "arch {}", base.cluster.arch.name());
        assert!(
            fast.1.conflicts >= 64,
            "a 64-wide same-bank gather must replay conflicts (got {})",
            fast.1.conflicts
        );
        // functional sanity: every output element is the broadcast word
        assert!(fast.0 .2.iter().all(|&b| f32::from_bits(b) == 42.5));
    }
}

/// Strided faxpy sweeps: `y[i] += a * x[i*stride]` strips across a
/// stride grid, dual-core, on both arches. Unit and power-of-two
/// strides exercise the closed-form conflict-free path; odd and wide
/// strides exercise the general replay path.
#[test]
fn strided_faxpy_sweeps_are_engine_invariant() {
    for base in [SimConfig::spatzformer(), SimConfig::baseline()] {
        for stride in [1i32, 2, 3, 4, 8, 16] {
            let faxpy = |name: &str, x_base: u32, y_base: u32| {
                let mut p = Program::new(name);
                for strip in 0..2u32 {
                    p.vector(VectorOp::SetVl { avl: 64, ew: ElemWidth::E32, lmul: Lmul::M8 });
                    p.vector(VectorOp::Load {
                        vd: VReg(8),
                        base: x_base + strip * 64 * 4,
                        stride,
                    });
                    p.vector(VectorOp::Load {
                        vd: VReg(16),
                        base: y_base + strip * 256,
                        stride: 1,
                    });
                    p.vector(VectorOp::MacVF { vd: VReg(16), vs: VReg(8), f: 3.0 });
                    p.vector(VectorOp::Store {
                        vs: VReg(16),
                        base: y_base + strip * 256,
                        stride: 1,
                    });
                }
                p.push(Instr::Fence);
                p.push(Instr::Halt);
                p
            };
            let programs = [faxpy("faxpy0", 0, 0x8000), faxpy("faxpy1", 0x1000, 0xA000)];
            let x: Vec<f32> = (0..2048).map(|i| (i as f32 * 0.37).cos()).collect();
            let y: Vec<f32> = (0..128).map(|i| i as f32).collect();
            let stage_f32 = vec![(0u32, x), (0x8000u32, y.clone()), (0xA000u32, y)];
            let run = |engine| {
                run_programs(&base, engine, &programs, &stage_f32, &[], (0x8000, 128))
            };
            assert_eq!(
                run(EngineKind::Fast),
                run(EngineKind::Naive),
                "arch {} stride {stride}",
                base.cluster.arch.name()
            );
        }
    }
}

/// Dual-core contention with DMA-staged inputs: both cores stream loads
/// from the same region (overlapping bank sets — the coupled fallback)
/// with barriers in between, after staging f32 *and* u32 arrays through
/// the DMA engine. Reports, TCDM conflict counts and DMA accounting must
/// all be byte-identical across engines.
#[test]
fn dual_core_and_dma_contention_is_engine_invariant() {
    let mk = |name: &str, stride: i32, out: u32| {
        let mut p = Program::new(name);
        for strip in 0..2u32 {
            p.vector(VectorOp::SetVl { avl: 96, ew: ElemWidth::E32, lmul: Lmul::M8 });
            p.vector(VectorOp::Load { vd: VReg(8), base: strip * 256, stride });
            p.vector(VectorOp::MulVF { vd: VReg(16), vs: VReg(8), f: 0.5 });
            p.vector(VectorOp::Store { vs: VReg(16), base: out + strip * 384, stride: 1 });
            p.push(Instr::Fence);
            p.push(Instr::Barrier);
        }
        p.push(Instr::Halt);
        p
    };
    let base = SimConfig::spatzformer();
    let programs = [mk("contend0", 1, 0x8000), mk("contend1", 2, 0xA000)];
    let x: Vec<f32> = (0..1024).map(|i| (i as f32).sin()).collect();
    let idx: Vec<u32> = (0..64u32).map(|i| i * 8).collect();
    let stage_f32 = vec![(0u32, x)];
    let stage_u32 = vec![(0x3000u32, idx)];
    let run = |engine| {
        run_programs(&base, engine, &programs, &stage_f32, &stage_u32, (0x8000, 192))
    };
    let fast = run(EngineKind::Fast);
    let naive = run(EngineKind::Naive);
    assert_eq!(fast, naive);
    assert!(fast.2 > 0, "DMA staging cycles must be accounted");
}

#[test]
fn watchdog_trips_identically_even_mid_skip() {
    // a real workload cut off mid-run: the trip point lands inside a
    // fast-forward window, exercising the horizon clamp
    for max_cycles in [60u64, 120, 250] {
        let run = |engine: EngineKind| {
            let mut cfg = SimConfig::spatzformer();
            cfg.max_cycles = max_cycles;
            cfg.engine = engine;
            let inst = KernelId::Fmatmul.build(
                &cfg.cluster,
                spatzformer::kernels::Deployment::SplitDual,
                7,
            );
            let mut cl = Cluster::new(cfg).unwrap();
            for (addr, d) in &inst.staging_f32 {
                cl.stage_f32(*addr, d);
            }
            for (addr, d) in &inst.staging_u32 {
                cl.stage_u32(*addr, d);
            }
            cl.load_programs([inst.programs[0].clone(), inst.programs[1].clone()])
                .unwrap();
            let err = cl.run().expect_err("budget is far too tight for fmatmul");
            (format!("{err:#}"), fingerprint(&cl, 0, 256))
        };
        assert_eq!(run(EngineKind::Fast), run(EngineKind::Naive), "max_cycles={max_cycles}");
    }
}

#[test]
fn watchdog_trips_identically_on_a_true_deadlock() {
    // barrier deadlock: every component's horizon is `None`, so the fast
    // engine jumps straight to the trip cycle in one skip
    let run = |engine: EngineKind| {
        let mut cfg = SimConfig::spatzformer();
        cfg.max_cycles = 5000;
        cfg.engine = engine;
        let mut cl = Cluster::new(cfg).unwrap();
        let mut p0 = Program::new("hang");
        for _ in 0..10 {
            p0.scalar(ScalarOp::Alu);
        }
        p0.push(Instr::Barrier);
        p0.push(Instr::Halt);
        cl.load_programs([p0, Program::idle()]).unwrap();
        cl.barrier_mut().set_participants(0b11);
        let err = cl.run().expect_err("deadlock must trip the watchdog");
        (format!("{err:#}"), fingerprint(&cl, 0, 16))
    };
    let fast = run(EngineKind::Fast);
    let naive = run(EngineKind::Naive);
    assert_eq!(fast, naive);
    assert_eq!(fast.1 .0, 5000, "trip cycle must be start + max_cycles");
}

/// FFT is the paper's fine-grained-sync headline: gather-heavy butterfly
/// stages with barriers between them, exactly the phases that used to
/// pin the fast engine to per-cycle replay. The engines must agree
/// byte-for-byte, and the fast engine must cover the run in fewer than
/// half as many steps as it simulates cycles.
#[test]
fn fft_fast_forwards_under_half_steps() {
    let run = |engine: EngineKind| {
        let mut cfg = SimConfig::spatzformer();
        cfg.engine = engine;
        let inst =
            KernelId::Fft.build(&cfg.cluster, spatzformer::kernels::Deployment::SplitDual, 1);
        let mut cl = Cluster::new(cfg).unwrap();
        let (m, out) = spatzformer::kernels::execute(&mut cl, &inst).unwrap();
        (m, out, cl.steps_executed())
    };
    let fast = run(EngineKind::Fast);
    let naive = run(EngineKind::Naive);
    assert_eq!((&fast.0, &fast.1), (&naive.0, &naive.1), "fft diverged between engines");
    assert!(
        fast.2 * 2 < fast.0.cycles,
        "fft must fast-forward most of its cycles: {} steps over {} cycles",
        fast.2,
        fast.0.cycles
    );
}

/// Overlapping-bank dual gathers plus scalar `WaitMem` traffic: both
/// LSUs broadcast-gather through the *same* bank (the coupled co-sim
/// path) while both scalar cores issue multi-cycle TCDM loads
/// (`tcdm_latency > 1`, the scalar memory-window path). The engines
/// must stay byte-identical, and the fast engine must cover the run in
/// fewer than half as many steps as it simulates cycles — i.e. neither
/// class may fall back to per-cycle replay.
#[test]
fn coupled_gathers_with_scalar_waitmem_fast_forward_under_half_steps() {
    let mk = |name: &str, idx_base: u32, out: u32| {
        let mut p = Program::new(name);
        for _ in 0..8 {
            p.scalar(ScalarOp::Load { addr: 0x1000 });
            p.scalar(ScalarOp::Alu);
        }
        p.vector(VectorOp::SetVl { avl: 64, ew: ElemWidth::E32, lmul: Lmul::M8 });
        p.vector(VectorOp::Load { vd: VReg(8), base: idx_base, stride: 1 });
        // every index names the same word: both units hammer one bank
        p.vector(VectorOp::LoadIndexed { vd: VReg(16), base: 0, vidx: VReg(8) });
        p.vector(VectorOp::Store { vs: VReg(16), base: out, stride: 1 });
        p.push(Instr::Fence);
        for _ in 0..8 {
            p.scalar(ScalarOp::Load { addr: 0x1200 });
            p.scalar(ScalarOp::Alu);
        }
        p.push(Instr::Halt);
        p
    };
    let programs = [mk("coupled-wm0", 0x2000, 0x6000), mk("coupled-wm1", 0x2400, 0x7000)];
    let run = |engine: EngineKind| {
        let mut cfg = SimConfig::spatzformer();
        cfg.engine = engine;
        cfg.cluster.tcdm_latency = 3;
        let mut cl = Cluster::new(cfg).unwrap();
        cl.stage_f32(0, &[0.0f32; 256]);
        cl.stage_f32(1024, &[7.25]);
        cl.stage_u32(0x2000, &[1024u32; 64]);
        cl.stage_u32(0x2400, &[1024u32; 64]);
        cl.load_programs([programs[0].clone(), programs[1].clone()]).unwrap();
        cl.run().unwrap();
        // one span covering both output regions (0x6000.. and 0x7000..)
        (fingerprint(&cl, 0x6000, 1088), cl.tcdm.stats.clone(), cl.steps_executed())
    };
    let fast = run(EngineKind::Fast);
    let naive = run(EngineKind::Naive);
    assert_eq!((&fast.0, &fast.1), (&naive.0, &naive.1), "engines diverged");
    let out = &fast.0 .2;
    assert!(out[..64].iter().all(|&b| f32::from_bits(b) == 7.25), "core 0 gather output");
    assert!(out[1024..].iter().all(|&b| f32::from_bits(b) == 7.25), "core 1 gather output");
    let cycles = fast.0 .0;
    assert!(
        fast.2 * 2 < cycles,
        "fast engine must cover coupled + scalar-mem phases in bulk: \
         {} steps over {} cycles",
        fast.2,
        cycles
    );
    assert!(fast.2 < naive.2, "naive must replay per cycle ({} vs {})", fast.2, naive.2);
}
