//! End-to-end integration: the simulated RVV datapath vs the AOT XLA
//! artifacts, for every kernel in every deployment.
//!
//! Requires `make artifacts` to have run (skips with a message
//! otherwise, so `cargo test` works before the Python build step) and
//! the `xla-runtime` cargo feature (the whole file is compiled out
//! without it — there is no golden model to compare against).

#![cfg(feature = "xla-runtime")]

use spatzformer::cluster::Cluster;
use spatzformer::config::SimConfig;
use spatzformer::coordinator::{Coordinator, Job, ModePolicy};
use spatzformer::kernels::{execute, Deployment, KernelId};
use spatzformer::runtime::XlaRuntime;
use spatzformer::util::stats::max_rel_err;

fn artifacts_dir() -> Option<std::path::PathBuf> {
    let dir = XlaRuntime::default_dir();
    if dir.join("manifest.txt").exists() {
        Some(dir)
    } else {
        eprintln!("skipping: no artifacts (run `make artifacts`)");
        None
    }
}

#[test]
fn every_kernel_every_deployment_matches_xla() {
    let Some(dir) = artifacts_dir() else { return };
    let mut rt = XlaRuntime::open(&dir).unwrap();
    for kernel in KernelId::all() {
        for deploy in [Deployment::SplitDual, Deployment::SplitSingle, Deployment::Merge] {
            let cfg = SimConfig::spatzformer();
            let inst = kernel.build(&cfg.cluster, deploy, 0xAB12);
            let mut cl = Cluster::new(cfg).unwrap();
            let (_, outputs) = execute(&mut cl, &inst).unwrap();
            let golden = rt.run(kernel.artifact(), &inst.artifact_inputs).unwrap();
            assert_eq!(golden.len(), outputs.len(), "{}", kernel.name());
            for (o, (sim, gold)) in outputs.iter().zip(golden.iter()).enumerate() {
                let err = max_rel_err(sim, gold);
                assert!(
                    err < 2e-2,
                    "{} {} output {o}: max rel err {err:.3e}",
                    kernel.name(),
                    deploy.name()
                );
            }
        }
    }
}

#[test]
fn baseline_cluster_matches_xla_too() {
    let Some(dir) = artifacts_dir() else { return };
    let mut c = Coordinator::new(SimConfig::baseline()).unwrap();
    c.attach_runtime(&dir).unwrap();
    for kernel in KernelId::all() {
        let r = c
            .submit(&Job::Kernel { kernel, policy: ModePolicy::Split })
            .unwrap();
        assert!(r.verified_max_rel_err.is_some(), "{}", kernel.name());
    }
}

#[test]
fn verification_catches_corruption() {
    // sanity for the harness itself: corrupting an input must fail
    let Some(dir) = artifacts_dir() else { return };
    let mut rt = XlaRuntime::open(&dir).unwrap();
    let cfg = SimConfig::spatzformer();
    let inst = KernelId::Faxpy.build(&cfg.cluster, Deployment::Merge, 0xAB12);
    let mut cl = Cluster::new(cfg).unwrap();
    let (_, outputs) = execute(&mut cl, &inst).unwrap();
    let mut bad_inputs = inst.artifact_inputs.clone();
    bad_inputs[1][0] += 100.0;
    let golden = rt.run("axpy", &bad_inputs).unwrap();
    let err = max_rel_err(&outputs[0], &golden[0]);
    assert!(err > 1e-2, "corruption went unnoticed (err={err:.3e})");
}

#[test]
fn runtime_rejects_wrong_arity_and_shapes() {
    let Some(dir) = artifacts_dir() else { return };
    let mut rt = XlaRuntime::open(&dir).unwrap();
    assert!(rt.run("axpy", &[vec![0.0; 8192]]).is_err(), "arity");
    assert!(
        rt.run("dotp", &[vec![0.0; 4], vec![0.0; 4]]).is_err(),
        "shape"
    );
    assert!(rt.run("nonexistent", &[]).is_err(), "unknown kernel");
}

#[test]
fn mixed_job_with_verification_end_to_end() {
    let Some(dir) = artifacts_dir() else { return };
    let mut c = Coordinator::new(SimConfig::spatzformer()).unwrap();
    c.attach_runtime(&dir).unwrap();
    let r = c
        .submit(&Job::Mixed {
            kernel: KernelId::Fft,
            policy: ModePolicy::Auto,
            coremark_iterations: 1,
        })
        .unwrap();
    assert!(r.verified_max_rel_err.unwrap() < 2e-2);
    assert!(r.scalar_cycles.is_some());
}
