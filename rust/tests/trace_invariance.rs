//! Observability invariants for the structured perf trace.
//!
//! Two claims from DESIGN.md §Observability are pinned here:
//!
//! 1. **Tracing is write-only.** Turning the `[trace]` knob on must
//!    never change a [`JobReport`] — not a counter, not a priced joule,
//!    not a result byte. Both the struct `PartialEq` and the canonical
//!    wire encoding ([`report_to_json`]) are compared, on *both* cycle
//!    engines, so neither the per-cycle loop nor the fast-forward paths
//!    can let observation perturb simulation.
//! 2. **The trace localizes real pathologies.** A same-bank indexed
//!    gather — every lane computes the identical address, defeating the
//!    XOR bank scrambler — must surface the TCDM as the top
//!    cycle-attribution line in `trace query`, again on both engines
//!    (the naive engine emits per-cycle conflict records, the fast
//!    engine closed-form span records; attribution must agree).

use spatzformer::cluster::Cluster;
use spatzformer::config::{EngineKind, SimConfig};
use spatzformer::coordinator::{Coordinator, Job, JobReport, ModePolicy};
use spatzformer::isa::{ElemWidth, Instr, Lmul, Program, VReg, VectorOp};
use spatzformer::kernels::KernelId;
use spatzformer::server::proto::report_to_json;
use spatzformer::trace::perf::{query, DEFAULT_WINDOW, Filter, Subsystem};

fn run_job(engine: EngineKind, trace: bool, job: &Job) -> JobReport {
    let mut cfg = SimConfig::spatzformer();
    cfg.engine = engine;
    cfg.trace = trace;
    let mut coord = Coordinator::new(cfg).expect("config must validate");
    coord.submit(job).expect("job must simulate")
}

#[test]
fn tracing_never_changes_a_job_report() {
    let jobs = [
        Job::Kernel { kernel: KernelId::Fft, policy: ModePolicy::Auto },
        Job::Mixed { kernel: KernelId::Fmatmul, policy: ModePolicy::Split, coremark_iterations: 2 },
    ];
    for engine in [EngineKind::Fast, EngineKind::Naive] {
        for job in &jobs {
            let off = run_job(engine, false, job);
            let on = run_job(engine, true, job);
            assert_eq!(off, on, "{engine:?}/{}: tracing changed the report", job.name());
            // Byte-level: the canonical wire encoding must be identical
            // too (telemetry is off the wire, so even record counts
            // cannot leak through).
            assert_eq!(
                report_to_json(&off).encode(),
                report_to_json(&on).encode(),
                "{engine:?}/{}: tracing changed the encoded report",
                job.name()
            );
        }
    }
}

/// Same-bank gather: stage 64 identical indices, then `LoadIndexed`
/// through them so every lane hits one bank every cycle.
fn conflict_program(cl: &mut Cluster) -> Program {
    cl.stage_u32(0x2000, &[1024u32; 64]);
    let mut p = Program::new("same-bank-gather");
    p.vector(VectorOp::SetVl { avl: 64, ew: ElemWidth::E32, lmul: Lmul::M8 });
    p.vector(VectorOp::Load { vd: VReg(8), base: 0x2000, stride: 1 });
    p.vector(VectorOp::LoadIndexed { vd: VReg(16), base: 0, vidx: VReg(8) });
    p.push(Instr::Fence);
    p.push(Instr::Halt);
    p
}

#[test]
fn trace_query_localizes_same_bank_conflicts_on_both_engines() {
    for engine in [EngineKind::Fast, EngineKind::Naive] {
        let mut cfg = SimConfig::spatzformer();
        cfg.engine = engine;

        // Untraced reference run.
        let mut plain = Cluster::new(cfg.clone()).unwrap();
        let p = conflict_program(&mut plain);
        plain.load_programs([p, Program::idle()]).unwrap();
        let plain_cycles = plain.run().unwrap();

        // Traced run: identical outcome, plus a queryable record log.
        cfg.trace = true;
        let mut traced = Cluster::new(cfg).unwrap();
        let p = conflict_program(&mut traced);
        traced.load_programs([p, Program::idle()]).unwrap();
        let traced_cycles = traced.run().unwrap();

        assert_eq!(plain_cycles, traced_cycles, "{engine:?}: tracing changed the cycle count");
        assert_eq!(plain.metrics(0), traced.metrics(0), "{engine:?}: tracing changed the metrics");
        assert!(
            traced.tcdm.stats.conflicts >= 63,
            "{engine:?}: same-address gather must conflict (got {})",
            traced.tcdm.stats.conflicts
        );

        let records = traced.trace().snapshot();
        assert!(!records.is_empty(), "{engine:?}: traced run emitted nothing");
        let report = query(&records, &Filter::default(), 5, DEFAULT_WINDOW);
        let top = report
            .attribution
            .first()
            .unwrap_or_else(|| panic!("{engine:?}: no attribution lines"));
        assert_eq!(
            top.subsystem,
            Subsystem::Tcdm,
            "{engine:?}: TCDM must top the attribution, got {:?}",
            report.attribution
        );
        assert!(
            top.cycles >= traced.tcdm.stats.conflicts,
            "{engine:?}: attributed TCDM cycles ({}) must cover the conflicts ({})",
            top.cycles,
            traced.tcdm.stats.conflicts
        );
    }
}

#[test]
fn filtered_query_isolates_the_tcdm_view() {
    let mut cfg = SimConfig::spatzformer();
    cfg.trace = true;
    let mut cl = Cluster::new(cfg).unwrap();
    let p = conflict_program(&mut cl);
    cl.load_programs([p, Program::idle()]).unwrap();
    cl.run().unwrap();

    let records = cl.trace().snapshot();
    let filter = Filter { subsystem: Some(Subsystem::Tcdm), ..Filter::default() };
    let report = query(&records, &filter, 5, DEFAULT_WINDOW);
    assert!(report.matched > 0, "subsystem filter must keep TCDM records");
    assert!(report.matched < report.total_records);
    assert_eq!(report.attribution.len(), 1);
    assert_eq!(report.attribution[0].subsystem, Subsystem::Tcdm);
}
