//! The in-place cluster-reuse contract: a job executed on a cluster that
//! already ran arbitrary other work and was `Cluster::reset` must be
//! **byte-identical** (exact `JobReport` equality, priced energy
//! included) to the same job on a freshly constructed cluster — on both
//! cycle-loop engines, across the kernel × deployment grid and mixed
//! jobs, and for seeded random job sequences.
//!
//! A fresh `Coordinator` per job is the oracle: its cluster has never
//! run anything, so its first submit is exactly the old
//! allocate-per-job pipeline. The reused side pushes every job through
//! one coordinator, so by the time the last job runs its cluster has
//! been polluted by — and reset after — every preceding job.

use spatzformer::config::{EngineKind, SimConfig};
use spatzformer::coordinator::{Coordinator, Job, JobReport, ModePolicy};
use spatzformer::fleet::scenario::{self, ScenarioKind};
use spatzformer::kernels::KernelId;
use spatzformer::util::testutil::check;

fn cfg_with(engine: EngineKind, baseline: bool) -> SimConfig {
    let mut cfg = if baseline {
        SimConfig::baseline()
    } else {
        SimConfig::spatzformer()
    };
    cfg.engine = engine;
    cfg
}

/// Oracle: every job on a brand-new coordinator (fresh cluster).
fn fresh_reports(cfg: &SimConfig, jobs: &[Job]) -> Vec<JobReport> {
    jobs.iter()
        .map(|job| {
            Coordinator::new(cfg.clone())
                .unwrap()
                .submit(job)
                .unwrap_or_else(|e| panic!("{}: {e:#}", job.name()))
        })
        .collect()
}

/// Subject: all jobs through one coordinator (one reset-reused cluster).
fn reused_reports(cfg: &SimConfig, jobs: &[Job]) -> Vec<JobReport> {
    let mut coord = Coordinator::new(cfg.clone()).unwrap();
    jobs.iter()
        .map(|job| {
            coord
                .submit(job)
                .unwrap_or_else(|e| panic!("{}: {e:#}", job.name()))
        })
        .collect()
}

fn assert_identical(cfg: &SimConfig, jobs: &[Job], label: &str) {
    let fresh = fresh_reports(cfg, jobs);
    let reused = reused_reports(cfg, jobs);
    for (i, (f, r)) in fresh.iter().zip(&reused).enumerate() {
        assert_eq!(
            f, r,
            "{label} [{}]: job {i} ({}) diverges between fresh and reused clusters",
            cfg.engine.name(),
            f.job_name
        );
    }
}

#[test]
fn grid_reuse_is_byte_identical_on_spatzformer() {
    // Every kernel through both forced deployments, then mixed with a
    // scalar co-task — consecutive jobs deliberately alternate split and
    // merge shapes so each reset has a differently-polluted cluster to
    // scrub (mode, VRFs, TCDM contents, icache, barrier episodes).
    let mut jobs = Vec::new();
    for kernel in KernelId::all() {
        for policy in [ModePolicy::Split, ModePolicy::Merge] {
            jobs.push(Job::Kernel { kernel, policy });
        }
        jobs.push(Job::Mixed {
            kernel,
            policy: ModePolicy::Auto,
            coremark_iterations: 1,
        });
    }
    for engine in [EngineKind::Fast, EngineKind::Naive] {
        assert_identical(&cfg_with(engine, false), &jobs, "spatzformer grid");
    }
}

#[test]
fn grid_reuse_is_byte_identical_on_a_quad_core_cluster() {
    // The same contract off the paper's dual-core shape: four cores per
    // cluster (merge pairs 0+1 and 2+3; mixed parks the co-task on core
    // 3), two clusters behind the shared staging tier. reset() must
    // scrub every per-core structure the wider shape grew.
    let mut jobs = Vec::new();
    for kernel in KernelId::all() {
        for policy in [ModePolicy::Split, ModePolicy::Merge] {
            jobs.push(Job::Kernel { kernel, policy });
        }
        jobs.push(Job::Mixed {
            kernel,
            policy: ModePolicy::Auto,
            coremark_iterations: 1,
        });
    }
    for engine in [EngineKind::Fast, EngineKind::Naive] {
        let mut cfg = cfg_with(engine, false);
        cfg.cluster.cores = 4;
        cfg.cluster.clusters = 2;
        assert_identical(&cfg, &jobs, "quad-core grid");
    }
}

#[test]
fn grid_reuse_is_byte_identical_on_baseline() {
    let mut jobs = Vec::new();
    for kernel in KernelId::all() {
        jobs.push(Job::Kernel { kernel, policy: ModePolicy::Split });
        jobs.push(Job::Mixed {
            kernel,
            policy: ModePolicy::Auto,
            coremark_iterations: 1,
        });
    }
    for engine in [EngineKind::Fast, EngineKind::Naive] {
        assert_identical(&cfg_with(engine, true), &jobs, "baseline grid");
    }
}

#[test]
fn prop_random_job_sequences_reuse_identical() {
    // Seeded random storms (mixed shapes, policies, iteration counts and
    // per-job workload seeds drawn from a pool): one coordinator with
    // per-job set_seed vs a fresh coordinator per job, random engine.
    check("reused cluster == fresh cluster over random sequences", 3, |g| {
        let engine = if g.bool() { EngineKind::Fast } else { EngineKind::Naive };
        let cfg = cfg_with(engine, false);
        let seed = g.rng.next_u64();
        let storm = scenario::generate(ScenarioKind::Storm, cfg.cluster.arch, seed, 8);

        let expected: Vec<JobReport> = storm
            .jobs
            .iter()
            .map(|fj| {
                let mut job_cfg = cfg.clone();
                if let Some(s) = fj.seed {
                    job_cfg.seed = s;
                }
                Coordinator::new(job_cfg).unwrap().submit(&fj.job).unwrap()
            })
            .collect();

        let mut coord = Coordinator::new(cfg.clone()).unwrap();
        for (i, fj) in storm.jobs.iter().enumerate() {
            coord.set_seed(fj.seed.unwrap_or(cfg.seed));
            let got = coord.submit(&fj.job).unwrap();
            assert_eq!(
                got, expected[i],
                "storm seed={seed:#x} engine={} job {i}",
                engine.name()
            );
        }
    });
}

#[test]
fn compile_cache_state_does_not_leak_across_seeds() {
    // One coordinator, alternating seeds: artifacts for both seeds stay
    // cached simultaneously and keep producing byte-identical reports.
    let job = Job::Mixed {
        kernel: KernelId::Fft,
        policy: ModePolicy::Merge,
        coremark_iterations: 2,
    };
    let mut coord = Coordinator::new(SimConfig::spatzformer()).unwrap();
    let mut per_seed: Vec<(u64, JobReport)> = Vec::new();
    for &seed in &[1u64, 2, 1, 2, 1] {
        coord.set_seed(seed);
        let r = coord.submit(&job).unwrap();
        let prev = per_seed.iter().position(|(s, _)| *s == seed);
        match prev {
            Some(i) => assert_eq!(per_seed[i].1, r, "seed {seed} must replay exactly"),
            None => per_seed.push((seed, r)),
        }
    }
    assert_eq!(per_seed.len(), 2, "two seeds, two cached artifacts");
    let cache = coord.compile_cache().unwrap();
    assert_eq!(cache.misses(), 2, "each seed compiles once");
    assert_eq!(cache.hits(), 3);
}
