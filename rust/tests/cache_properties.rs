//! Property tests for the fleet result-cache digest (`fleet::cache::job_key`).
//!
//! The digest guards DESIGN.md's invariant that *scheduling must never
//! change results*: execution-strategy knobs (the `[fleet]` and
//! `[compile]` sections and the `[sim] engine` choice) are excluded from
//! the key, while everything that determines a simulation outcome —
//! cluster shape, PPA model, workload seed, cycle limit, trace flag, the
//! job itself — must split the key space.

use spatzformer::config::{ArchKind, Corner, EngineKind, SimConfig};
use spatzformer::coordinator::{Job, ModePolicy};
use spatzformer::fleet::cache::job_key;
use spatzformer::kernels::KernelId;
use spatzformer::util::testutil::{check, Gen};

fn arb_job(g: &mut Gen) -> Job {
    let kernel = *g.choose(&KernelId::all());
    let policy = *g.choose(&[ModePolicy::Split, ModePolicy::Merge, ModePolicy::Auto]);
    if g.bool() {
        Job::Kernel { kernel, policy }
    } else {
        Job::Mixed {
            kernel,
            policy,
            coremark_iterations: g.int(1, 8) as u32,
        }
    }
}

fn arb_base(g: &mut Gen) -> SimConfig {
    let mut cfg = if g.bool() {
        SimConfig::spatzformer()
    } else {
        SimConfig::baseline()
    };
    cfg.seed = g.rng.next_u64();
    cfg
}

#[test]
fn prop_scheduling_knobs_never_change_the_key() {
    check("fleet/compile/engine knobs leave the key unchanged", 128, |g| {
        let cfg = arb_base(g);
        let job = arb_job(g);
        let key = job_key(&cfg, &job);
        let mut mutated = cfg.clone();
        // mutate every scheduling knob at once with random values
        mutated.fleet.workers = g.int(0, 64);
        mutated.fleet.cache = g.bool();
        mutated.compile.cache = g.bool();
        mutated.engine = if g.bool() {
            EngineKind::Naive
        } else {
            EngineKind::Fast
        };
        // ... including the whole [server] section: where a cluster is
        // served from must never change what it computes
        mutated.server.addr = format!("10.0.0.{}:{}", g.int(1, 254), g.int(1024, 65535));
        mutated.server.queue_depth = g.int(1, 4096);
        mutated.server.workers = g.int(0, 64);
        mutated.server.batch_report_limit = g.int(0, 1024);
        mutated.server.drain_ms = g.int(0, 60_000) as u64;
        // observability knobs ride in [server] precisely so they stay
        // out of the digest: service tracing must never split the cache
        mutated.server.trace = g.bool();
        mutated.server.trace_capacity = g.int(1, 1 << 20);
        mutated.server.trace_out = format!("svc-{}.sptz", g.int(0, 999));
        mutated.server.probe_ms = g.int(1, 60_000) as u64;
        mutated.server.probe_threshold = g.int(1, 16);
        assert_eq!(
            job_key(&mutated, &job),
            key,
            "scheduling knobs must not split the key space: {:?}/{:?}/{:?}/{:?}/{:?}",
            mutated.fleet.workers,
            mutated.fleet.cache,
            mutated.compile.cache,
            mutated.engine,
            mutated.server
        );
        // the compile key ignores them too
        use spatzformer::compile::compile_key;
        assert_eq!(
            compile_key(&mutated.cluster, mutated.seed, &job),
            compile_key(&cfg.cluster, cfg.seed, &job)
        );
    });
}

#[test]
fn prop_compile_key_tracks_artifact_identity() {
    // The compile-stage key must ignore everything the result key tracks
    // beyond the artifact inputs (PPA, cycle limit, trace, engine,
    // scheduling sections) yet split on cluster shape, seed and job.
    use spatzformer::compile::compile_key;
    check("compile key = f(cluster, seed, job) only", 128, |g| {
        let cfg = arb_base(g);
        let job = arb_job(g);
        let key = compile_key(&cfg.cluster, cfg.seed, &job);
        // stability
        assert_eq!(key, compile_key(&cfg.cluster, cfg.seed, &job));
        // seed and shape sensitivity
        assert_ne!(key, compile_key(&cfg.cluster, cfg.seed ^ (1 + g.rng.next_u64() % 0xFF), &job));
        let mut wider = cfg.cluster.clone();
        wider.vlen_bits *= 2;
        assert_ne!(key, compile_key(&wider, cfg.seed, &job));
        // job sensitivity via the Debug-encoding identity rule
        let other = arb_job(g);
        if format!("{job:?}") == format!("{other:?}") {
            assert_eq!(key, compile_key(&cfg.cluster, cfg.seed, &other));
        } else {
            assert_ne!(key, compile_key(&cfg.cluster, cfg.seed, &other));
        }
    });
}

#[test]
fn prop_result_determining_knobs_change_the_key() {
    check("cluster/ppa/seed/limit knobs change the key", 256, |g| {
        let cfg = arb_base(g);
        let job = arb_job(g);
        let key = job_key(&cfg, &job);
        let mut mutated = cfg.clone();
        let which = g.int(0, 10);
        match which {
            0 => mutated.seed ^= 1 + g.rng.next_u64() % 0xFFFF,
            1 => mutated.max_cycles += 1 + g.int(1, 1000) as u64,
            2 => mutated.trace = !mutated.trace,
            9 => mutated.trace_capacity += 1 + g.int(1, 1024),
            3 => mutated.cluster.lanes *= 2,
            4 => mutated.cluster.vlen_bits *= 2,
            5 => mutated.cluster.tcdm_banks *= 2,
            6 => {
                mutated.cluster.arch = match mutated.cluster.arch {
                    ArchKind::Baseline => ArchKind::Spatzformer,
                    ArchKind::Spatzformer => ArchKind::Baseline,
                }
            }
            7 => mutated.ppa.pj_barrier += 0.25 + g.rng.next_f64(),
            8 => {
                mutated.ppa.corner = match mutated.ppa.corner {
                    Corner::Tt => Corner::Ss,
                    Corner::Ss => Corner::Tt,
                }
            }
            _ => mutated.cluster.mode_switch_latency += 1 + g.int(1, 32) as u64,
        }
        assert_ne!(
            job_key(&mutated, &job),
            key,
            "mutation {which} must change the key"
        );
    });
}

#[test]
fn prop_topology_fields_split_both_digests() {
    // The N-core × M-cluster knobs are artifact inputs: mutating either
    // must re-key the compile artifact AND the result cache — a stale
    // hit across shapes would replay the wrong per-core programs.
    use spatzformer::compile::compile_key;
    check("cores/clusters mutations split compile and result keys", 128, |g| {
        let cfg = arb_base(g);
        let job = arb_job(g);
        let rkey = job_key(&cfg, &job);
        let ckey = compile_key(&cfg.cluster, cfg.seed, &job);
        let mut mutated = cfg.clone();
        if g.bool() {
            mutated.cluster.cores += g.int(1, 6);
        } else {
            mutated.cluster.clusters += g.int(1, 6);
        }
        assert_ne!(job_key(&mutated, &job), rkey, "result digest must track the topology");
        assert_ne!(
            compile_key(&mutated.cluster, mutated.seed, &job),
            ckey,
            "compile digest must track the topology"
        );
    });
}

#[test]
fn default_dual_core_digests_ignore_spelled_out_topology_defaults() {
    // Cache-churn guard for the paper's shape: the digest preimage (the
    // cluster's Debug rendering) omits `clusters` when it is 1, so a
    // config that spells out the default topology hashes identically to
    // one that never touched the fields — existing dual-core cache
    // entries and golden digests stay valid.
    use spatzformer::compile::compile_key;
    let cfg = SimConfig::spatzformer();
    assert_eq!((cfg.cluster.cores, cfg.cluster.clusters), (2, 1));
    let mut spelled = cfg.clone();
    spelled.cluster.cores = 2;
    spelled.cluster.clusters = 1;
    let job = Job::Kernel { kernel: KernelId::Fft, policy: ModePolicy::Merge };
    assert_eq!(job_key(&cfg, &job), job_key(&spelled, &job));
    assert_eq!(
        compile_key(&cfg.cluster, cfg.seed, &job),
        compile_key(&spelled.cluster, cfg.seed, &job)
    );
    let d = format!("{:?}", cfg.cluster);
    assert!(d.contains("cores: 2"), "{d}");
    assert!(!d.contains("clusters"), "preimage must omit the default cluster count: {d}");
}

#[test]
fn prop_job_identity_decides_key_equality() {
    check("same job same key, different job different key", 256, |g| {
        let cfg = arb_base(g);
        let a = arb_job(g);
        let b = arb_job(g);
        assert_eq!(job_key(&cfg, &a), job_key(&cfg, &a), "digest must be stable");
        // Jobs carry no PartialEq (by design); their Debug encoding is
        // exhaustive, which is exactly what the digest folds in.
        if format!("{a:?}") == format!("{b:?}") {
            assert_eq!(job_key(&cfg, &a), job_key(&cfg, &b));
        } else {
            assert_ne!(job_key(&cfg, &a), job_key(&cfg, &b));
        }
    });
}
