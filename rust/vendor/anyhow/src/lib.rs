//! Minimal offline stand-in for the `anyhow` crate.
//!
//! The build environment has no crates.io access, so this vendored path
//! dependency provides exactly the surface the workspace uses:
//!
//! * [`Error`] — a string-backed error with a context chain;
//! * [`Result`] — `Result<T, Error>` alias;
//! * [`anyhow!`], [`bail!`], [`ensure!`] — construction macros;
//! * [`Context`] — `.context(..)` / `.with_context(..)` on `Result` and
//!   `Option`.
//!
//! Semantics match `anyhow` where the workspace relies on them: `{e}`
//! displays the outermost context, `{e:#}` joins the whole chain with
//! `": "`, `{e:?}` prints an anyhow-style `Caused by:` listing, and any
//! `std::error::Error + Send + Sync + 'static` converts via `?`.
//! Unlike the real crate the original error value is flattened to a
//! string (no downcasting) — nothing in this workspace downcasts.

use std::error::Error as StdError;
use std::fmt;

/// String-backed error with a chain of context frames.
///
/// `msg` is the innermost (root) message; `frames` holds context pushed
/// around it, innermost first.
pub struct Error {
    msg: String,
    frames: Vec<String>,
}

impl Error {
    /// Build an error from any displayable message.
    pub fn msg<M: fmt::Display>(message: M) -> Self {
        Error {
            msg: message.to_string(),
            frames: Vec::new(),
        }
    }

    /// Wrap the error with an outer context frame.
    pub fn context<C: fmt::Display>(mut self, context: C) -> Self {
        self.frames.push(context.to_string());
        self
    }

    /// Outermost message (what bare `{}` shows).
    fn outermost(&self) -> &str {
        self.frames.last().map(String::as_str).unwrap_or(&self.msg)
    }

    /// Messages outermost-first.
    fn chain(&self) -> Vec<&str> {
        let mut v: Vec<&str> = self.frames.iter().rev().map(String::as_str).collect();
        v.push(&self.msg);
        v
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            write!(f, "{}", self.chain().join(": "))
        } else {
            write!(f, "{}", self.outermost())
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let chain = self.chain();
        write!(f, "{}", chain[0])?;
        if chain.len() > 1 {
            write!(f, "\n\nCaused by:")?;
            for (i, cause) in chain[1..].iter().enumerate() {
                if chain.len() > 2 {
                    write!(f, "\n    {i}: {cause}")?;
                } else {
                    write!(f, "\n    {cause}")?;
                }
            }
        }
        Ok(())
    }
}

// `Error` intentionally does NOT implement `std::error::Error`; that is
// what makes this blanket conversion coherent (same trick as real anyhow).
impl<E: StdError + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Self {
        let mut frames = Vec::new();
        let mut source = e.source();
        while let Some(s) = source {
            frames.push(s.to_string());
            source = s.source();
        }
        // sources are inner-more than `e` itself: innermost last in the
        // source walk, so the root message is the deepest source.
        if let Some(root) = frames.pop() {
            let mut out = Error {
                msg: root,
                frames: Vec::new(),
            };
            for frame in frames.into_iter().rev() {
                out = out.context(frame);
            }
            out.context(e.to_string())
        } else {
            Error::msg(e)
        }
    }
}

/// `Result<T, anyhow::Error>` alias.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Context extension for `Result` and `Option`.
pub trait Context<T> {
    /// Wrap the error (or `None`) with a context message.
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T>;

    /// Wrap the error (or `None`) with a lazily evaluated context message.
    fn with_context<C, F>(self, f: F) -> Result<T>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C;
}

impl<T, E: Into<Error>> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T> {
        self.map_err(|e| e.into().context(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.map_err(|e| e.into().context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a message or format string.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
}

/// Return early with an error.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an error if a condition is false.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            return Err($crate::anyhow!(concat!("condition failed: ", stringify!($cond))));
        }
    };
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            return Err($crate::anyhow!($($arg)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "missing file")
    }

    #[test]
    fn display_shows_outermost_alternate_shows_chain() {
        let e: Error = Error::from(io_err()).context("opening config");
        assert_eq!(format!("{e}"), "opening config");
        assert_eq!(format!("{e:#}"), "opening config: missing file");
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn inner() -> Result<u32> {
            let n: u32 = "42".parse()?;
            Ok(n)
        }
        assert_eq!(inner().unwrap(), 42);
        fn bad() -> Result<u32> {
            let n: u32 = "nope".parse()?;
            Ok(n)
        }
        assert!(bad().is_err());
    }

    #[test]
    fn ensure_and_bail_and_anyhow() {
        fn check(x: u32) -> Result<u32> {
            ensure!(x < 10, "x too big: {x}");
            if x == 7 {
                bail!("unlucky {}", x);
            }
            Ok(x)
        }
        assert_eq!(check(3).unwrap(), 3);
        assert_eq!(format!("{}", check(12).unwrap_err()), "x too big: 12");
        assert_eq!(format!("{}", check(7).unwrap_err()), "unlucky 7");
        let e = anyhow!("plain");
        assert_eq!(format!("{e}"), "plain");
    }

    #[test]
    fn context_on_result_and_option() {
        let r: std::result::Result<(), std::io::Error> = Err(io_err());
        let e = r.context("step one").unwrap_err();
        assert_eq!(format!("{e:#}"), "step one: missing file");

        let o: Option<u32> = None;
        let e = o.with_context(|| format!("missing {}", "thing")).unwrap_err();
        assert_eq!(format!("{e}"), "missing thing");
    }

    #[test]
    fn debug_prints_cause_chain() {
        let e: Error = Error::from(io_err()).context("outer");
        let dbg = format!("{e:?}");
        assert!(dbg.starts_with("outer"));
        assert!(dbg.contains("Caused by:"));
        assert!(dbg.contains("missing file"));
    }
}
