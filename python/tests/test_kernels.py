"""L1 correctness: Pallas kernels vs the pure-jnp oracle (ref.py).

Hypothesis sweeps shapes (and tile configurations) for the matmul tile
kernel and FFT sizes for the butterfly pipeline; every case asserts
allclose against ref.py.
"""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st
from numpy.testing import assert_allclose

from compile.kernels import fft_pallas, matmul_pallas, ref

RNG = np.random.default_rng(0xC0FFEE)


def rand(shape, lo=-1.0, hi=1.0):
    return RNG.uniform(lo, hi, size=shape).astype(np.float32)


# ---------------------------------------------------------------- matmul

def test_matmul_fixed_shape_matches_ref():
    a, b = rand((64, 64)), rand((64, 128))
    got = matmul_pallas.matmul(a, b)
    assert_allclose(np.asarray(got), np.asarray(ref.matmul(a, b)), rtol=1e-5, atol=1e-5)


@settings(max_examples=25, deadline=None)
@given(
    mt=st.integers(1, 8),
    kt=st.integers(1, 6),
    nt=st.integers(1, 4),
    bm=st.sampled_from([2, 4, 8]),
    bn=st.sampled_from([8, 16, 32]),
)
def test_matmul_shape_sweep(mt, kt, nt, bm, bn):
    m, k, n = mt * bm, kt * 8, nt * bn
    a, b = rand((m, k)), rand((k, n))
    got = matmul_pallas.matmul(a, b, bm=bm, bn=bn)
    assert got.shape == (m, n)
    assert_allclose(np.asarray(got), np.asarray(ref.matmul(a, b)), rtol=1e-4, atol=1e-5)


def test_matmul_rejects_untiled_shapes():
    with pytest.raises(AssertionError):
        matmul_pallas.matmul(rand((65, 64)), rand((64, 128)))


def test_matmul_identity():
    a = np.eye(32, dtype=np.float32)
    b = rand((32, 64))
    got = matmul_pallas.matmul(a, b, bm=8, bn=32)
    assert_allclose(np.asarray(got), b, rtol=1e-6)


# ------------------------------------------------------------------- fft

def test_fft_stage_tables_match_radix2_structure():
    a, b, wre, wim = fft_pallas.stage_tables(16, 1)
    # stage 1: h=2 -> pairs (0,2),(1,3),(4,6),...
    assert list(a[:4]) == [0, 1, 4, 5]
    assert list(b[:4]) == [2, 3, 6, 7]
    # every element appears exactly once across a and b
    assert sorted(list(a) + list(b)) == list(range(16))
    assert np.allclose(wre**2 + wim**2, 1.0, atol=1e-6)


@settings(max_examples=10, deadline=None)
@given(bits=st.integers(3, 9), seed=st.integers(0, 2**31 - 1))
def test_fft_matches_jnp_fft(bits, seed):
    n = 1 << bits
    rng = np.random.default_rng(seed)
    re = rng.uniform(-1, 1, n).astype(np.float32)
    im = rng.uniform(-1, 1, n).astype(np.float32)
    got_re, got_im = fft_pallas.fft(re, im)
    want_re, want_im = ref.fft_split(re, im)
    assert_allclose(np.asarray(got_re), np.asarray(want_re), rtol=2e-3, atol=2e-3)
    assert_allclose(np.asarray(got_im), np.asarray(want_im), rtol=2e-3, atol=2e-3)


def test_fft_impulse_is_flat_spectrum():
    n = 64
    re = np.zeros(n, np.float32)
    re[0] = 1.0
    im = np.zeros(n, np.float32)
    got_re, got_im = fft_pallas.fft(re, im)
    assert_allclose(np.asarray(got_re), np.ones(n, np.float32), atol=1e-6)
    assert_allclose(np.asarray(got_im), np.zeros(n, np.float32), atol=1e-6)


def test_fft_linearity():
    n = 128
    x1, y1 = rand(n), rand(n)
    x2, y2 = rand(n), rand(n)
    r1, i1 = fft_pallas.fft(x1, y1)
    r2, i2 = fft_pallas.fft(x2, y2)
    r12, i12 = fft_pallas.fft(x1 + x2, y1 + y2)
    assert_allclose(np.asarray(r12), np.asarray(r1) + np.asarray(r2), rtol=1e-3, atol=1e-3)
    assert_allclose(np.asarray(i12), np.asarray(i1) + np.asarray(i2), rtol=1e-3, atol=1e-3)


# ------------------------------------------------------------ other refs

def test_conv2d_valid_against_naive():
    img, k = rand((16, 16)), rand((3, 3))
    got = np.asarray(ref.conv2d_valid(img, k))
    want = np.zeros((14, 14), np.float32)
    for i in range(14):
        for j in range(14):
            want[i, j] = float((img[i : i + 3, j : j + 3] * k).sum())
    assert_allclose(got, want, rtol=1e-4, atol=1e-5)


def test_dct_matrix_orthonormal():
    d = ref.dct_matrix()
    assert_allclose(d @ d.T, np.eye(8, dtype=np.float32), atol=1e-6)


def test_dct_blockwise_equals_per_block_transform():
    img = rand((64, 64))
    got = np.asarray(ref.dct2_blockwise(img))
    d = ref.dct_matrix()
    for bi in range(0, 64, 8):
        for bj in range(0, 64, 8):
            block = img[bi : bi + 8, bj : bj + 8]
            want = d @ block @ d.T
            assert_allclose(got[bi : bi + 8, bj : bj + 8], want, rtol=1e-4, atol=1e-5)


@settings(max_examples=20, deadline=None)
@given(n=st.integers(4, 512), seed=st.integers(0, 2**31 - 1))
def test_axpy_and_dotp_sweep(n, seed):
    rng = np.random.default_rng(seed)
    x = rng.uniform(-1, 1, n).astype(np.float32)
    y = rng.uniform(-1, 1, n).astype(np.float32)
    alpha = np.asarray([0.75], np.float32)
    assert_allclose(np.asarray(ref.axpy(alpha, x, y)), y + 0.75 * x, rtol=1e-6)
    assert_allclose(
        np.asarray(ref.dotp(x, y)),
        np.asarray([np.dot(x.astype(np.float64), y.astype(np.float64))], np.float32),
        rtol=1e-3,
        atol=1e-4,
    )
