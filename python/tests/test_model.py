"""L2 correctness: the model's compute graphs at artifact shapes."""

import numpy as np
from numpy.testing import assert_allclose

from compile import model
from compile.kernels import ref

RNG = np.random.default_rng(7)


def rand(shape):
    return RNG.uniform(-1, 1, size=shape).astype(np.float32)


def test_specs_cover_all_six_kernels():
    names = [name for name, _, _ in model.specs()]
    assert names == ["matmul", "conv2d", "fft", "dotp", "axpy", "dct"]


def test_all_models_run_at_artifact_shapes():
    for name, fn, in_specs in model.specs():
        args = [rand(shape) for shape, _ in in_specs]
        outs = fn(*args)
        assert isinstance(outs, tuple), name
        for o in outs:
            assert np.all(np.isfinite(np.asarray(o))), name


def test_matmul_model_matches_ref():
    a, b = rand((64, 64)), rand((64, 128))
    (got,) = model.matmul(a, b)
    assert_allclose(np.asarray(got), np.asarray(ref.matmul(a, b)), rtol=1e-5, atol=1e-5)


def test_fft_model_matches_jnp_fft():
    re, im = rand(256), rand(256)
    got_re, got_im = model.fft(re, im)
    want_re, want_im = ref.fft_split(re, im)
    assert_allclose(np.asarray(got_re), np.asarray(want_re), rtol=2e-3, atol=2e-3)
    assert_allclose(np.asarray(got_im), np.asarray(want_im), rtol=2e-3, atol=2e-3)


def test_dotp_model_shape_is_vector_of_one():
    x, y = rand(8192), rand(8192)
    (got,) = model.dotp(x, y)
    assert got.shape == (1,)


def test_axpy_model():
    alpha = np.asarray([0.75], np.float32)
    x, y = rand(8192), rand(8192)
    (got,) = model.axpy(alpha, x, y)
    assert_allclose(np.asarray(got), y + 0.75 * x, rtol=1e-6)


def test_conv_output_shape():
    img, k = rand((64, 64)), rand((3, 3))
    (got,) = model.conv2d(img, k)
    assert got.shape == (62, 62)
