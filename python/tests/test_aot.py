"""AOT emission: every kernel lowers to non-trivial HLO text plus a
manifest the Rust runtime can parse."""

import os

from compile import aot, model


def test_lower_all_emits_artifacts(tmp_path):
    written = aot.lower_all(str(tmp_path))
    names = [name for name, _, _ in model.specs()]
    for name in names:
        path = tmp_path / f"{name}.hlo.txt"
        assert path.exists(), name
        text = path.read_text()
        assert "HloModule" in text, name
        assert "ROOT" in text, name
        # the Rust loader needs a tuple root (return_tuple=True)
        assert "tuple" in text, name
        # elided constants would silently read back as zeros (regression
        # guard: print_large_constants=True must stay on)
        assert "constant({...})" not in text, name
    manifest = (tmp_path / "manifest.txt").read_text()
    for name in names:
        assert f"{name}:" in manifest
    assert "matmul: in=64x64,64x128 out=64x128" in manifest
    assert "fft: in=256,256 out=256,256" in manifest
    assert "axpy: in=1,8192,8192 out=8192" in manifest
    assert len(written) == len(names) + 1


def test_shape_str():
    assert aot.shape_str((64, 128)) == "64x128"
    assert aot.shape_str((256,)) == "256"
    assert aot.shape_str(()) == "1"
