# Build-time-only package: authors the kernels (L1 Pallas), the compute
# graphs (L2 JAX) and AOT-lowers them to HLO text artifacts consumed by
# the Rust runtime. Never imported on the request path.
