"""L2: the JAX compute graphs for the paper's six-kernel suite.

Each function is the golden model of one simulated kernel, at the exact
shapes the simulator runs (see `rust/src/kernels/*`). The two
highest-arithmetic-intensity kernels call the L1 Pallas kernels
(`kernels.matmul_pallas`, `kernels.fft_pallas`); the rest are plain jnp.
`aot.py` lowers each once to an HLO-text artifact for the Rust runtime —
Python never runs at request time.
"""

import jax.numpy as jnp

from .kernels import fft_pallas, matmul_pallas, ref

# Shapes fixed to the simulator's workloads (kernels::*::{M,K,N,...}).
MATMUL_M, MATMUL_K, MATMUL_N = 64, 64, 128
CONV_IN, CONV_K = 64, 3
FFT_N = 256
DOTP_N = 8192
AXPY_N = 8192
DCT_DIM = 8 * 8  # 64x64 image, 8x8 blocks


def matmul(a, b):
    """fmatmul: C[64,128] = A[64,64] @ B[64,128] via the Pallas tile
    kernel."""
    return (matmul_pallas.matmul(a, b),)


def conv2d(img, k):
    """conv2d: 3x3 valid cross-correlation over 64x64 -> 62x62."""
    return (ref.conv2d_valid(img, k),)


def fft(re, im):
    """fft: 256-point radix-2 DIT, split-complex, via the Pallas
    butterfly-stage kernel."""
    return fft_pallas.fft(re, im)


def dotp(x, y):
    """fdotp: inner product of 8192-element vectors -> (1,)."""
    return (ref.dotp(x, y),)


def axpy(alpha, x, y):
    """faxpy: y + alpha*x over 8192 elements (alpha is a (1,) array)."""
    return (ref.axpy(alpha, x, y),)


def dct(img):
    """fdct: blockwise 8x8 2-D DCT-II over a 64x64 image. The per-block
    transform D X D^T is two small matmuls; they ride through the same
    einsum the oracle uses (fused by XLA), keeping the artifact exactly
    equal to the reference."""
    return (ref.dct2_blockwise(img),)


def specs():
    """(name, fn, input shapes) for every artifact, in manifest order."""
    f32 = jnp.float32
    return [
        ("matmul", matmul, [((MATMUL_M, MATMUL_K), f32), ((MATMUL_K, MATMUL_N), f32)]),
        ("conv2d", conv2d, [((CONV_IN, CONV_IN), f32), ((CONV_K, CONV_K), f32)]),
        ("fft", fft, [((FFT_N,), f32), ((FFT_N,), f32)]),
        ("dotp", dotp, [((DOTP_N,), f32), ((DOTP_N,), f32)]),
        ("axpy", axpy, [((1,), f32), ((AXPY_N,), f32), ((AXPY_N,), f32)]),
        ("dct", dct, [((DCT_DIM, DCT_DIM), f32)]),
    ]
