# L1: Pallas kernels for the compute hot-spots (tiled matmul, radix-2
# FFT butterfly stage) plus the pure-jnp oracle in ref.py.
