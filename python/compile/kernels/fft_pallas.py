"""L1 Pallas kernel: radix-2 DIT butterfly stage, split-complex fp32.

One stage updates all N elements: butterfly pairs (a, b) with twiddle w
compute ``a' = a + w*b`` and ``b' = a - w*b``. The per-stage pairing and
twiddles are compile-time constants (static tables, exactly like the
index/twiddle tables the simulated kernel stages into the TCDM), so the
kernel body is pure vector arithmetic plus static gathers — which is why
it lowers to plain HLO under ``interpret=True`` and runs on the Rust
PJRT CPU client.
"""

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl


@functools.lru_cache(maxsize=None)
def stage_tables(n: int, s: int):
    """(a indices, b indices, twiddle re, twiddle im) for stage ``s``.

    Identical tables to the Rust generator (`kernels::fft::stage_tables`),
    with indices in elements rather than bytes.
    """
    h = 1 << s
    a_idx, b_idx, w_re, w_im = [], [], [], []
    for g in range(0, n, 2 * h):
        for j in range(h):
            a = g + j
            a_idx.append(a)
            b_idx.append(a + h)
            ang = -np.pi * j / h
            w_re.append(np.cos(ang))
            w_im.append(np.sin(ang))
    return (
        np.asarray(a_idx, np.int32),
        np.asarray(b_idx, np.int32),
        np.asarray(w_re, np.float32),
        np.asarray(w_im, np.float32),
    )


def _stage_kernel(re_ref, im_ref, aidx_ref, bidx_ref, wre_ref, wim_ref, ore_ref, oim_ref):
    re = re_ref[...]
    im = im_ref[...]
    a_idx = aidx_ref[...]
    b_idx = bidx_ref[...]
    w_re = wre_ref[...]
    w_im = wim_ref[...]
    ar, ai = re[a_idx], im[a_idx]
    br, bi = re[b_idx], im[b_idx]
    # t = w * b (split-complex), same operation order as the simulator
    t_im = w_re * bi + w_im * br
    t_re = w_re * br - w_im * bi
    new_re = re.at[a_idx].set(ar + t_re).at[b_idx].set(ar - t_re)
    new_im = im.at[a_idx].set(ai + t_im).at[b_idx].set(ai - t_im)
    ore_ref[...] = new_re
    oim_ref[...] = new_im


def fft_stage(re: jax.Array, im: jax.Array, s: int):
    """Apply butterfly stage ``s`` to split-complex arrays of length N.

    The stage tables travel as kernel *inputs* (Pallas does not capture
    constant arrays) — mirroring the simulated kernel, which loads the
    very same tables from the TCDM."""
    n = re.shape[0]
    a_idx, b_idx, w_re, w_im = stage_tables(n, s)
    return pl.pallas_call(
        _stage_kernel,
        out_shape=(
            jax.ShapeDtypeStruct((n,), jnp.float32),
            jax.ShapeDtypeStruct((n,), jnp.float32),
        ),
        interpret=True,
    )(
        re,
        im,
        jnp.asarray(a_idx),
        jnp.asarray(b_idx),
        jnp.asarray(w_re),
        jnp.asarray(w_im),
    )


def fft(re: jax.Array, im: jax.Array):
    """Full radix-2 DIT FFT from Pallas stage kernels (N power of two)."""
    n = re.shape[0]
    bits = int(np.log2(n))
    assert 1 << bits == n, f"N={n} must be a power of two"
    brv = np.array(
        [int(f"{i:0{bits}b}"[::-1], 2) for i in range(n)], dtype=np.int32
    )
    re, im = re[brv], im[brv]
    for s in range(bits):
        re, im = fft_stage(re, im, s)
    return re, im
