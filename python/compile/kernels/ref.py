"""Pure-jnp oracle for every kernel — the correctness reference the
Pallas kernels and the L2 model are tested against (and, transitively,
what the Rust simulator's RVV datapath is verified against through the
AOT artifacts)."""

import jax.numpy as jnp
import numpy as np


def matmul(a, b):
    """C = A @ B, fp32."""
    return jnp.dot(a, b, preferred_element_type=jnp.float32)


def conv2d_valid(img, k):
    """3x3 valid cross-correlation (no kernel flip), matching the
    simulated kernel's tap order."""
    kh, kw = k.shape
    oh = img.shape[0] - kh + 1
    ow = img.shape[1] - kw + 1
    out = jnp.zeros((oh, ow), jnp.float32)
    for ki in range(kh):
        for kj in range(kw):
            out = out + k[ki, kj] * img[ki : ki + oh, kj : kj + ow]
    return out


def fft_split(re, im):
    """FFT of split-complex input via jnp.fft (the gold standard the
    radix-2 pallas pipeline is checked against)."""
    x = re.astype(jnp.complex64) + 1j * im.astype(jnp.complex64)
    y = jnp.fft.fft(x)
    return jnp.real(y).astype(jnp.float32), jnp.imag(y).astype(jnp.float32)


def dotp(x, y):
    """Inner product, accumulated in fp32 -> shape (1,)."""
    return jnp.dot(x, y, preferred_element_type=jnp.float32).reshape(1)


def axpy(alpha, x, y):
    """y + alpha*x; alpha arrives as a (1,)-shaped array."""
    return y + alpha[0] * x


def dct_matrix(b: int = 8) -> np.ndarray:
    """The 8x8 DCT-II matrix — identical to `kernels::fdct::dct_matrix`."""
    d = np.zeros((b, b), np.float32)
    for u in range(b):
        scale = np.sqrt(1.0 / b) if u == 0 else np.sqrt(2.0 / b)
        for c in range(b):
            d[u, c] = scale * np.cos((2 * c + 1) * u * np.pi / (2 * b))
    return d


def dct2_blockwise(img, b: int = 8):
    """Blockwise 2-D DCT-II: Y_block = D X_block D^T for every 8x8 block
    of a (64, 64) image."""
    d = jnp.asarray(dct_matrix(b))
    n = img.shape[0]
    nb = n // b
    # x[i, r, j, c]: block (i, j), in-block row r, in-block column c
    x = img.reshape(nb, b, nb, b)
    # Y[i, u, j, v] = sum_{r, c} D[u, r] * X[i, r, j, c] * D[v, c]
    y = jnp.einsum("ur,irjc,vc->iujv", d, x, d, preferred_element_type=jnp.float32)
    return y.reshape(n, n)
