"""L1 Pallas kernel: tiled fp32 matmul.

The VRF-blocking discipline of the simulated Spatz fmatmul kernel mapped
to Pallas: the grid tiles C into (BM, BN) blocks (the accumulator tile
lives in VMEM like the vfmacc accumulator group lives in the VRF), and
each grid step streams the A row-panel and B column-panel it needs.

``interpret=True`` everywhere: the CPU PJRT backend cannot run Mosaic
custom-calls, and the AOT artifacts must execute inside the Rust runtime
(see DESIGN.md §Hardware-Adaptation).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Default tile shape: matches one VRF-sized accumulator strip of the
# simulated kernel (2 rows x 128-column vector at LMUL=8).
DEF_BM = 8
DEF_BN = 64


def _matmul_kernel(a_ref, b_ref, o_ref):
    # One (BM, BN) tile of C: full-K contraction of the A row-panel with
    # the B column-panel, accumulated in fp32.
    o_ref[...] = jnp.dot(a_ref[...], b_ref[...], preferred_element_type=jnp.float32)


@functools.partial(jax.jit, static_argnames=("bm", "bn"))
def matmul(a: jax.Array, b: jax.Array, bm: int = DEF_BM, bn: int = DEF_BN) -> jax.Array:
    """C = A @ B with a tiled Pallas kernel (fp32).

    Shapes must tile evenly: M % bm == 0 and N % bn == 0.
    """
    m, k = a.shape
    k2, n = b.shape
    assert k == k2, f"contraction mismatch: {a.shape} @ {b.shape}"
    assert m % bm == 0 and n % bn == 0, f"({m},{n}) not tiled by ({bm},{bn})"
    grid = (m // bm, n // bn)
    return pl.pallas_call(
        _matmul_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, k), lambda i, j: (i, 0)),  # A row-panel
            pl.BlockSpec((k, bn), lambda i, j: (0, j)),  # B column-panel
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=True,
    )(a, b)
