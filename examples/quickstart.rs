//! Quickstart: run one vector kernel on the Spatzformer cluster in both
//! modes and print the paper-style metrics.
//!
//! ```sh
//! cargo run --release --example quickstart
//! ```

use spatzformer::config::SimConfig;
use spatzformer::coordinator::{Coordinator, Job, ModePolicy};
use spatzformer::kernels::KernelId;
use spatzformer::runtime::XlaRuntime;

fn main() -> anyhow::Result<()> {
    // 1. a coordinator over the reconfigurable cluster
    let mut coord = Coordinator::new(SimConfig::spatzformer())?;

    // 2. optional: attach the AOT artifacts so every run is cross-checked
    //    against the XLA golden model (requires `make artifacts` and a
    //    build with `--features xla-runtime`; degrade gracefully otherwise)
    let artifacts = XlaRuntime::default_dir();
    if artifacts.join("manifest.txt").exists() {
        match coord.attach_runtime(&artifacts) {
            Ok(()) => println!("XLA verification: ON\n"),
            Err(e) => println!("XLA verification: OFF ({e})\n"),
        }
    } else {
        println!("XLA verification: OFF (run `make artifacts`)\n");
    }

    // 3. run the FFT in split mode and merge mode
    for policy in [ModePolicy::Split, ModePolicy::Merge] {
        let report = coord.submit(&Job::Kernel { kernel: KernelId::Fft, policy })?;
        println!("fft in {:?} mode ({})", policy, report.deploy.name());
        println!("  cycles      : {}", report.kernel_cycles);
        println!("  FLOP/cycle  : {:.3}", report.flop_per_cycle());
        println!("  GFLOPS/W    : {:.2}", report.metrics.gflops_per_watt());
        if let Some(err) = report.verified_max_rel_err {
            println!("  verified    : OK (max rel err {err:.2e} vs XLA)");
        }
        println!();
    }
    Ok(())
}
