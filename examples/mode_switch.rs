//! Runtime reconfiguration demo: a single program that interleaves
//! split-mode and merge-mode phases (§II: "the operational mode can also
//! change at runtime"), with the drain/switch protocol visible in the
//! cycle accounting.

use spatzformer::cluster::Cluster;
use spatzformer::config::{Mode, SimConfig};
use spatzformer::isa::{ElemWidth, Instr, Lmul, Program, ScalarOp, VReg, VectorOp};

fn main() -> anyhow::Result<()> {
    let mut cluster = Cluster::new(SimConfig::spatzformer())?;

    // stage a 1 KiB vector of data
    let n: u32 = 1024;
    let data: Vec<f32> = (0..n).map(|i| (i as f32 * 0.01).sin()).collect();
    cluster.stage_f32(0, &data);

    // phase 1 (split): scale the first half at vl<=128
    // phase 2 (merge): scale the second half at vl<=256
    // phase 3 (split again): add 1.0 to everything
    let mut p = Program::new("phased");
    p.scalar(ScalarOp::Csr); // mode status read
    let emit_scale = |p: &mut Program, lo: u32, hi: u32, vl_cap: u32, f: f32, out: u32| {
        let mut off = lo;
        while off < hi {
            let vl = vl_cap.min(hi - off);
            p.vector(VectorOp::SetVl { avl: vl, ew: ElemWidth::E32, lmul: Lmul::M8 });
            p.vector(VectorOp::Load { vd: VReg(8), base: off * 4, stride: 1 });
            p.vector(VectorOp::MulVF { vd: VReg(16), vs: VReg(8), f });
            p.vector(VectorOp::Store { vs: VReg(16), base: out + off * 4, stride: 1 });
            off += vl;
        }
    };
    emit_scale(&mut p, 0, n / 2, 128, 2.0, 0x8000);
    p.push(Instr::SetMode(Mode::Merge));
    emit_scale(&mut p, n / 2, n, 256, 2.0, 0x8000);
    p.push(Instr::SetMode(Mode::Split));
    let mut off = 0;
    while off < n {
        p.vector(VectorOp::SetVl { avl: 128.min(n - off), ew: ElemWidth::E32, lmul: Lmul::M8 });
        p.vector(VectorOp::Load { vd: VReg(8), base: 0x8000 + off * 4, stride: 1 });
        p.vector(VectorOp::AddVF { vd: VReg(16), vs: VReg(8), f: 1.0 });
        p.vector(VectorOp::Store { vs: VReg(16), base: 0x8000 + off * 4, stride: 1 });
        off += 128.min(n - off);
    }
    p.push(Instr::Fence);
    p.push(Instr::Halt);

    cluster.load_programs([p, Program::idle()])?;
    let cycles = cluster.run()?;

    // verify
    let out = cluster.tcdm.read_f32_slice(0x8000, n as usize);
    for (i, (&o, &d)) in out.iter().zip(data.iter()).enumerate() {
        assert_eq!(o, d * 2.0 + 1.0, "elem {i}");
    }

    println!("phased split/merge/split program: {} cycles", cycles);
    println!("mode switches    : {}", cluster.counters.mode_switches);
    println!("final mode       : {}", cluster.mode().name());
    println!("broadcast events : {}", cluster.counters.broadcast_dispatch);
    println!("unit busy cycles : {:?}", cluster.counters.cycles_unit_busy);
    println!("all {} elements verified: out = 2*x + 1", n);
    Ok(())
}
