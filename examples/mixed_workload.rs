//! END-TO-END DRIVER — the paper's headline use case, exercised across
//! all layers on a real (small) workload:
//!
//! every vector kernel of the suite runs concurrently with the
//! CoreMark-workalike scalar task, in split mode (kernel confined to one
//! core+unit) and in merge mode (one core drives both units, the other
//! core runs the scalar task). Each kernel's output is cross-checked
//! against its JAX/Pallas AOT artifact through the PJRT runtime, proving
//! L1 (Pallas) -> L2 (JAX) -> HLO text -> Rust PJRT -> simulated RVV
//! datapath all agree, while the cycle metrics reproduce Fig. 2's right
//! axis (MM speedup ~1.8x average).
//!
//! ```sh
//! make artifacts && cargo run --release --example mixed_workload
//! ```

use spatzformer::config::SimConfig;
use spatzformer::coordinator::{Coordinator, Job, ModePolicy};
use spatzformer::kernels::KernelId;
use spatzformer::metrics::Table;
use spatzformer::runtime::XlaRuntime;
use spatzformer::util::Summary;

fn main() -> anyhow::Result<()> {
    let mut coord = Coordinator::new(SimConfig::spatzformer())?;
    let artifacts = XlaRuntime::default_dir();
    if artifacts.join("manifest.txt").exists() {
        // Degrade gracefully: attach fails on builds without the
        // `xla-runtime` feature, and the sweep is still worth running.
        if let Err(e) = coord.attach_runtime(&artifacts) {
            eprintln!("warning: running unverified ({e})");
        }
    } else {
        eprintln!("warning: artifacts missing; run `make artifacts` for XLA verification");
    }

    let mut table = Table::new(&[
        "kernel ∥ coremark",
        "SM kernel cyc",
        "MM kernel cyc",
        "MM speedup",
        "coremark crc",
        "verified",
    ]);
    let mut speedups = Summary::new();

    for kernel in KernelId::all() {
        let sm = coord.submit(&Job::Mixed {
            kernel,
            policy: ModePolicy::Split,
            coremark_iterations: 1,
        })?;
        let mm = coord.submit(&Job::Mixed {
            kernel,
            policy: ModePolicy::Merge,
            coremark_iterations: 1,
        })?;
        assert_eq!(sm.coremark_checksum, mm.coremark_checksum, "work proof");
        let speedup = sm.kernel_cycles as f64 / mm.kernel_cycles as f64;
        speedups.push(speedup);
        table.row(&[
            kernel.name().into(),
            sm.kernel_cycles.to_string(),
            mm.kernel_cycles.to_string(),
            format!("{speedup:.2}x"),
            format!("{:#06x}", mm.coremark_checksum.unwrap()),
            match mm.verified_max_rel_err {
                Some(e) => format!("OK ({e:.1e})"),
                None => "-".into(),
            },
        ]);
    }
    table.row(&[
        "average".into(),
        "".into(),
        "".into(),
        format!("{:.2}x", speedups.geomean()),
        "".into(),
        "".into(),
    ]);

    println!("Mixed scalar-vector workload (Fig. 2, right axis)");
    println!("{}", table.render());
    println!(
        "paper: average 1.8x, best ~2x | measured: average {:.2}x, best {:.2}x",
        speedups.geomean(),
        speedups.max()
    );
    Ok(())
}
