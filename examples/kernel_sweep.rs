//! Kernel sweep: the full Fig. 2 left axis (performance + energy
//! efficiency) across baseline / SM / MM, printed as tables — the same
//! harness the bench targets use.

use spatzformer::experiments;

fn main() {
    let seed = 0xC0FFEE;
    let rows = experiments::fig2_rows(seed);
    println!("=== Fig. 2 left axis — performance ===");
    println!("{}", experiments::render_fig2_perf(&rows));
    println!("=== Fig. 2 left axis — energy efficiency ===");
    println!("{}", experiments::render_fig2_energy(&rows));
    println!("=== area (E4) ===");
    println!("{}", experiments::render_area());
    println!("=== fmax (E5) ===");
    println!("{}", experiments::render_fmax());
}
